//! Differential suite: every REGISTERED built-in workload serialized to
//! the JSON cascade schema, re-parsed, and evaluated must produce
//! bit-identical `CascadeStats` to the in-code cascade — across
//! contention off/on and a sample of taxonomy points. This is the
//! contract that keeps the built-in generators and the `--workload
//! FILE` loader from ever drifting: built-ins ARE serializable
//! definitions, and the schema can express exactly what they generate.

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::arch::topology::ContentionMode;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::util::json::Json;
use harp::workload::registry;
use harp::workload::Cascade;

/// Serialize → re-parse a built-in's cascade, asserting the document
/// fixpoint on the way.
fn round_trip(key: &str, spec: &registry::WorkloadSpec) -> (Cascade, Cascade) {
    let direct = spec.cascade();
    let text = spec.to_json().to_string_pretty();
    let reparsed = Cascade::from_json(&Json::parse(&text).expect("valid JSON"))
        .unwrap_or_else(|e| panic!("{key}: {e}"));
    assert_eq!(
        reparsed.to_json().to_string_pretty(),
        text,
        "{key}: serialize(parse(serialize(x))) must be byte-identical"
    );
    (direct, reparsed)
}

#[test]
fn builtin_vs_json_cascades_evaluate_bit_identically() {
    // One homogeneous and one shared-node machine: the latter is where
    // contention booking actually changes the map space, so both the
    // Off and Booked pipelines see every family.
    let classes = ["leaf+homo", "hier+xnode"];
    for (key, spec) in registry::all_builtins() {
        let (direct, reparsed) = round_trip(key, &spec);
        for class_id in classes {
            let class = HarpClass::from_id(class_id).expect("taxonomy id");
            for contention in [ContentionMode::Off, ContentionMode::Booked] {
                let mut opts = EvalOptions { samples: 8, ..EvalOptions::default() };
                opts.contention = contention;
                let a = evaluate_cascade_on_config(
                    &class,
                    &HardwareParams::default(),
                    &direct,
                    &opts,
                )
                .unwrap_or_else(|e| panic!("{key} on {class_id}: {e}"));
                let b = evaluate_cascade_on_config(
                    &class,
                    &HardwareParams::default(),
                    &reparsed,
                    &opts,
                )
                .unwrap_or_else(|e| panic!("{key} (reparsed) on {class_id}: {e}"));
                assert_eq!(
                    a.stats.to_json().to_string_pretty(),
                    b.stats.to_json().to_string_pretty(),
                    "{key} on {class_id} ({contention:?}): stats drifted between the \
                     in-code cascade and its JSON round trip"
                );
            }
        }
    }
}

/// Allocation-policy back-compat: under the default `alloc: greedy`,
/// every registered built-in's stats document keeps the EXACT key set
/// and order it had before the allocation-policy engine existed — no
/// `alloc`/`assignment` keys — so the committed figure goldens and old
/// disk-spilled caches cannot move. (The greedy assignment itself is
/// produced by the byte-identical historical allocator; this pins the
/// serialization half of that contract.) A non-default policy on the
/// same point DOES carry the two extra keys, immediately after
/// `machine`.
#[test]
fn greedy_stats_json_keeps_pre_policy_engine_byte_shape() {
    const LEGACY_KEYS: [&str; 16] = [
        "workload",
        "machine",
        "latency_cycles",
        "energy_pj",
        "mults_per_joule",
        "macs",
        "mac_energy_pj",
        "noc_energy_pj",
        "offchip_energy_pj",
        "energy_by_level",
        "onchip_energy_by_role",
        "buffer_energy_by_role",
        "energy_by_phase",
        "busy_fraction",
        "utilization_timeline",
        "node_contention",
    ];
    let class = HarpClass::from_id("hier+xnode").expect("taxonomy id");
    let keys_of = |j: &Json| -> Vec<String> {
        match j {
            Json::Obj(pairs) => pairs.iter().map(|(k, _)| k.clone()).collect(),
            other => panic!("stats document is not an object: {other:?}"),
        }
    };
    for (key, spec) in registry::all_builtins() {
        let opts = EvalOptions { samples: 8, ..EvalOptions::default() };
        let r = evaluate_cascade_on_config(
            &class,
            &HardwareParams::default(),
            &spec.cascade(),
            &opts,
        )
        .unwrap_or_else(|e| panic!("{key}: {e}"));
        assert_eq!(r.stats.alloc_policy, "greedy", "{key}");
        assert_eq!(keys_of(&r.stats.to_json()), LEGACY_KEYS.to_vec(), "{key}");
    }
    // The non-default shape, once (not per builtin — it is policy-, not
    // workload-, dependent).
    let mut opts = EvalOptions { samples: 8, ..EvalOptions::default() };
    opts.alloc = harp::hhp::allocator::AllocPolicy::RoundRobin;
    let r = evaluate_cascade_on_config(
        &class,
        &HardwareParams::default(),
        &registry::by_name("bert").unwrap().cascade(),
        &opts,
    )
    .unwrap();
    let keys = keys_of(&r.stats.to_json());
    assert_eq!(keys[..4], ["workload", "machine", "alloc", "assignment"]);
    assert_eq!(keys.len(), LEGACY_KEYS.len() + 2);
}

/// The structural half of the contract, cheap enough to run over every
/// field of every op: the re-parsed cascade IS the generated one.
#[test]
fn reparsed_cascades_are_structurally_identical() {
    for (key, spec) in registry::all_builtins() {
        let (direct, reparsed) = round_trip(key, &spec);
        assert_eq!(direct.name, reparsed.name, "{key}");
        assert_eq!(direct.deps, reparsed.deps, "{key}");
        assert_eq!(direct.ops.len(), reparsed.ops.len(), "{key}");
        for (a, b) in direct.ops.iter().zip(&reparsed.ops) {
            assert_eq!(a.name, b.name, "{key}");
            assert_eq!(a.kind, b.kind, "{key}/{}", a.name);
            assert_eq!(a.phase, b.phase, "{key}/{}", a.name);
            assert_eq!(
                (a.b, a.m, a.n, a.k, a.count),
                (b.b, b.m, b.n, b.k, b.count),
                "{key}/{}",
                a.name
            );
        }
        assert_eq!(direct.total_macs(), reparsed.total_macs(), "{key}");
    }
}
