//! Property-based invariants over the cost model, mapper, scheduler and
//! substrates, via the from-scratch `util::prop` runner.

use harp::arch::partition::{HardwareParams, MachineConfig, Role};
use harp::arch::spec::{ArchSpec, MappingConstraints};
use harp::arch::taxonomy::HarpClass;
use harp::arch::topology::{AccelNode, ContentionMode, MachineTopology};
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::coordinator::figures::Evaluator;
use harp::hhp::scheduler::{schedule, ScheduleOptions};
use harp::mapper::blackbox::BlackboxMapper;
use harp::mapper::search::{search_best, search_best_threaded, SearchBudget};
use harp::model::nest::analyze;
use harp::util::json::Json;
use harp::util::prop::{check, Gen};
use harp::util::rng::Rng;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};
use harp::workload::intensity::Classifier;
use harp::workload::transformer;
use std::sync::Arc;

fn test_spec() -> ArchSpec {
    ArchSpec::leaf("p", 16, 16, 64, 32768, 1 << 20, 128.0, 32.0)
}

/// The mapper always returns a structurally valid mapping whose DRAM
/// traffic is at least the compulsory footprint, and never claims more
/// active PEs than exist.
#[test]
fn prop_mapper_output_valid_and_traffic_bounded() {
    let spec = test_spec();
    let gen = Gen::ranges(vec![(1, 96), (1, 256), (1, 256), (1, 4)]);
    check("mapper-valid", 0xA1, 12, &gen, |v| {
        let op = TensorOp::bmm("p", Phase::Encoder, v[3] as u64, v[0] as u64, v[1] as u64, v[2] as u64);
        let r = search_best(&op, &spec, &SearchBudget { samples: 40, seed: 7 });
        r.mapping.validate(&op, &spec).map_err(|e| e.to_string())?;
        if r.stats.dram_words + 1e-9 < op.footprint_words() as f64 {
            return Err(format!(
                "dram words {} below compulsory {}",
                r.stats.dram_words,
                op.footprint_words()
            ));
        }
        if r.mapping.active_pes() > spec.rows * spec.cols {
            return Err("too many active PEs".into());
        }
        Ok(())
    });
}

/// Tentpole invariant of the parallel sweep engine: for a fixed
/// `SearchBudget.seed`, the batched pipeline returns an identical best
/// mapping and bit-identical `OpStats` for every worker count
/// (`HARP_THREADS` ∈ {1, 4, 16} — passed explicitly so the property
/// holds regardless of the ambient environment).
#[test]
fn prop_search_identical_across_thread_counts() {
    let spec = test_spec();
    let gen = Gen::ranges(vec![(1, 128), (1, 192), (1, 192), (1, 3)]);
    check("search-thread-determinism", 0x5D, 8, &gen, |v| {
        let op = TensorOp::bmm(
            "p",
            Phase::Encoder,
            v[3] as u64,
            v[0] as u64,
            v[1] as u64,
            v[2] as u64,
        );
        let b = SearchBudget { samples: 50, seed: 0x5EED ^ v[0] as u64 };
        let base = search_best_threaded(&op, &spec, &b, 1);
        for threads in [4usize, 16] {
            let r = search_best_threaded(&op, &spec, &b, threads);
            if r.mapping != base.mapping {
                return Err(format!("best mapping differs at {threads} threads"));
            }
            if r.stats.cycles != base.stats.cycles
                || r.stats.energy_pj != base.stats.energy_pj
                || r.stats.dram_words != base.stats.dram_words
            {
                return Err(format!("OpStats differ at {threads} threads"));
            }
            if r.evaluated != base.evaluated || r.valid != base.valid {
                return Err(format!("search accounting differs at {threads} threads"));
            }
        }
        Ok(())
    });
}

/// Cross-run evaluation cache: a cache hit returns the same allocation,
/// and its contents are bit-identical to a fresh, uncached evaluation.
#[test]
fn evaluator_cache_hits_equal_fresh_search() {
    let opts = EvalOptions { samples: 40, ..EvalOptions::default() };
    let ev = Evaluator::new(opts.clone());
    let wl = harp::workload::WorkloadSpec::Transformer(transformer::bert_large());
    let class = HarpClass::from_id("leaf+xnode").unwrap();

    let first = ev.eval(&wl, &class, 2048.0, None);
    let hit = ev.eval(&wl, &class, 2048.0, None);
    assert!(Arc::ptr_eq(&first, &hit), "second lookup must be a cache hit");

    let cascade = wl.cascade();
    let params = HardwareParams { dram_bw_bits: 2048.0, ..HardwareParams::default() };
    let fresh = evaluate_cascade_on_config(&class, &params, &cascade, &opts).unwrap();
    assert_eq!(first.latency_cycles, fresh.stats.latency_cycles);
    assert_eq!(first.energy_pj, fresh.stats.energy_pj);
    assert_eq!(first.macs, fresh.stats.macs);
    assert_eq!(first.busy_fraction, fresh.stats.busy_fraction);
    assert_eq!(first.utilization_timeline, fresh.stats.utilization_timeline);
}

/// Nest analysis: energy and cycles are positive, the energy components
/// sum to the total, and utilisation stays in (0, 1].
#[test]
fn prop_nest_analysis_consistency() {
    let spec = test_spec();
    let gen = Gen::ranges(vec![(1, 128), (1, 128), (1, 128)]);
    check("nest-consistency", 0xB2, 20, &gen, |v| {
        let op = TensorOp::gemm("p", Phase::Encoder, v[0] as u64, v[1] as u64, v[2] as u64);
        let r = search_best(&op, &spec, &SearchBudget { samples: 30, seed: 3 });
        let s = &r.stats;
        if s.cycles <= 0.0 || s.energy_pj <= 0.0 {
            return Err("non-positive cost".into());
        }
        let sum: f64 = s.levels.iter().map(|l| l.energy_pj).sum::<f64>()
            + s.mac_energy_pj
            + s.noc_energy_pj;
        if (sum - s.energy_pj).abs() > 1e-6 * s.energy_pj {
            return Err(format!("energy components {sum} != total {}", s.energy_pj));
        }
        if !(s.utilization > 0.0 && s.utilization <= 1.0 + 1e-9) {
            return Err(format!("utilisation {} out of range", s.utilization));
        }
        if s.cycles + 1e-9 < s.compute_cycles {
            return Err("latency below compute bound".into());
        }
        Ok(())
    });
}

/// Scheduler: critical path ≤ makespan ≤ serial sum, for random DAGs
/// with random assignments to a 2-unit machine.
#[test]
fn prop_scheduler_bounds() {
    let machine = MachineConfig::build(
        &HarpClass::from_id("leaf+xnode").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let gen = Gen::ranges(vec![(2, 12), (0, u32::MAX as usize)]);
    check("scheduler-bounds", 0xC3, 25, &gen, |v| {
        let n = v[0];
        let mut rng = Rng::new(v[1] as u64 + 1);
        let mut g = Cascade::new("rand");
        for i in 0..n {
            g.push(TensorOp::gemm(&format!("o{i}"), Phase::Encoder, 8, 8, 8));
        }
        // Random forward edges (acyclic by construction).
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.next_f64() < 0.3 {
                    g.dep(i, j);
                }
            }
        }
        let mapper = BlackboxMapper::with_budget(SearchBudget { samples: 10, seed: 2 });
        let assignment: Vec<usize> = (0..n).map(|_| rng.next_below(2)).collect();
        let mapped = mapper.map_cascade(&g, &machine, &assignment);
        let sched = schedule(&g, &machine, &mapped, &ScheduleOptions::default());
        let lat = |i: usize| mapped[i].stats.cycles * g.ops[i].count as f64;
        let cp = g.critical_path(lat);
        let serial: f64 = (0..n).map(lat).sum();
        if sched.makespan + 1e-6 < cp {
            return Err(format!("makespan {} below critical path {cp}", sched.makespan));
        }
        if sched.makespan > serial + 1e-6 {
            return Err(format!("makespan {} above serial sum {serial}", sched.makespan));
        }
        // Every op scheduled exactly once.
        if sched.intervals.len() != n {
            return Err("not all ops scheduled".into());
        }
        Ok(())
    });
}

/// Machine building conserves resources for every valid taxonomy point:
/// PEs within rounding of the budget, LLB shares never exceed the total.
/// LLB capacity is summed over *tree nodes* — several units may share
/// one LLB node, so summing flattened specs would double-count it.
#[test]
fn prop_partitioner_conserves_resources() {
    use harp::arch::level::LevelKind;
    let ids = [
        "leaf+homo", "leaf+xnode", "leaf+intra", "hier+xdepth", "hier+homo", "hier+xnode",
        "hier+xnode-cl", "hier+compound",
    ];
    let gen = Gen::ranges(vec![(0, ids.len() - 1), (256, 8192), (1, 3)]);
    check("partitioner-conserves", 0xD4, 30, &gen, |v| {
        let class = HarpClass::from_id(ids[v[0]]).unwrap();
        let params = HardwareParams {
            total_macs: (v[1] as u64) * 8, // keep factorisable
            dram_bw_bits: [512.0, 1024.0, 2048.0][v[2] - 1],
            ..HardwareParams::default()
        };
        let m = MachineConfig::build(&class, &params)?;
        let total = m.total_pes();
        if total > params.total_macs {
            return Err(format!("PEs {total} exceed budget {}", params.total_macs));
        }
        if (total as f64) < params.total_macs as f64 * 0.80 {
            return Err(format!("PEs {total} lose >20% of budget {}", params.total_macs));
        }
        let llb_total: u64 = m
            .topology
            .nodes
            .iter()
            .filter(|n| !n.passthrough && n.parent.is_some() && n.kind == LevelKind::LLB)
            .map(|n| n.size_words)
            .sum();
        if llb_total > params.llb_bytes {
            return Err(format!("LLB {llb_total} exceeds {}", params.llb_bytes));
        }
        let bw_total: f64 =
            m.sub_accels.iter().map(|s| s.spec.dram().bw_words_per_cycle).sum();
        if bw_total > params.dram_bw_words() + 1e-6 {
            return Err(format!("bw {bw_total} exceeds {}", params.dram_bw_words()));
        }
        Ok(())
    });
}

/// Tentpole invariant of the topology generator, as a property over
/// random hardware budgets: `classify(generate(class, params))` returns
/// exactly `class`, for every point the taxonomy can express.
#[test]
fn prop_generate_classify_round_trip() {
    let points = HarpClass::all_points();
    let gen = Gen::ranges(vec![(0, points.len() - 1), (256, 8192), (1, 3)]);
    check("generate-classify-round-trip", 0xF7, 40, &gen, |v| {
        let class = &points[v[0]];
        let params = HardwareParams {
            total_macs: (v[1] as u64) * 8,
            dram_bw_bits: [512.0, 1024.0, 2048.0][v[2] - 1],
            ..HardwareParams::default()
        };
        let m = MachineConfig::build(class, &params)?;
        let back = m.classify()?;
        if back != *class {
            return Err(format!("{class} classified as {back}"));
        }
        // The flattened view and the tree agree on unit count and PEs.
        if m.sub_accels.len() != m.topology.accels.len() {
            return Err("sub_accels/topology length mismatch".into());
        }
        let tree_pes: u64 = m.topology.accels.iter().map(|a| a.peak_macs()).sum();
        if tree_pes != m.total_pes() {
            return Err(format!("tree PEs {tree_pes} != flattened {}", m.total_pes()));
        }
        Ok(())
    });
}

/// A root → LLB tree with `k` units co-attached at the shared LLB.
/// `pes[i]` sizes unit `i`'s array; every unit gets an equal DRAM share.
fn co_attached_machine(pes: &[u64]) -> MachineTopology {
    let k = pes.len() as f64;
    let mut t = MachineTopology::new("co", 256.0);
    let llb = t.add_node(0, harp::arch::level::LevelKind::LLB, "llb.shared", 1 << 16, 128.0, None);
    for (i, &p) in pes.iter().enumerate() {
        t.add_accel(AccelNode {
            label: format!("u{i}"),
            ty: format!("ty{i}"),
            role: Role::Unified,
            rows: 1,
            cols: p,
            rf_bytes_per_pe: 64,
            attach: llb,
            attach_bw: 64.0,
            dram_share: 256.0 / k,
            capacity_share: None,
            mac_energy_pj: 0.2,
            fsm_group: None,
            constraints: MappingConstraints::default(),
        });
    }
    t.validate().unwrap();
    t
}

/// Contention invariant #1: adding a co-attached unit never *increases*
/// another unit's booked capacity or granted bandwidth — so it can
/// never decrease that unit's op latency. Checked over random array
/// sizes for growing co-attachment counts, against the same fixed
/// memory-bound op.
#[test]
fn prop_adding_co_attached_unit_never_decreases_latency() {
    use harp::arch::level::LevelKind;
    let gen = Gen::ranges(vec![(1, 64), (1, 64), (1, 64), (1, 64)]);
    check("co-attach-monotone", 0xCA11, 25, &gen, |v| {
        let pes: Vec<u64> = v.iter().map(|&x| x as u64).collect();
        // A fixed op on unit 0, bound by the shared LLB uplink + DRAM.
        let mut stats = harp::model::stats::OpStats::new_empty();
        stats.compute_cycles = 1.0;
        stats.boundary_words = vec![(LevelKind::LLB, 640.0), (LevelKind::DRAM, 2560.0)];
        let mut prev_cap = u64::MAX;
        let mut prev_lat = 0.0f64;
        for k in 1..=pes.len() {
            let t = co_attached_machine(&pes[..k]);
            let m = MachineConfig::from_topology(t)
                .map_err(|e| e.to_string())?
                .with_contention(ContentionMode::Booked)?;
            let cap = m.sub_accels[0].spec.levels[1].size_words;
            if cap > prev_cap {
                return Err(format!("booked capacity grew from {prev_cap} to {cap} at k={k}"));
            }
            prev_cap = cap;
            let busy = vec![true; k];
            let lat = stats.latency_with_boundary_bw(&m.contended_boundary_bw(0, &busy));
            if lat + 1e-9 < prev_lat {
                return Err(format!("op latency dropped from {prev_lat} to {lat} at k={k}"));
            }
            prev_lat = lat;
            // Booked slices always sum to the shared node exactly.
            let total: u64 =
                (0..k).map(|s| m.sub_accels[s].spec.levels[1].size_words).sum();
            if k >= 2 && total != 1 << 16 {
                return Err(format!("slices sum to {total}, node is {}", 1u64 << 16));
            }
        }
        Ok(())
    });
}

/// Contention invariant #2: shrinking the busy set never shrinks any
/// boundary grant (idle siblings only ever *give back* bandwidth), over
/// random busy subsets of the clustered hierarchical machine.
#[test]
fn prop_idle_regrant_is_monotone() {
    let m = MachineConfig::build(
        &HarpClass::from_id("hier+xnode-cl").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap()
    .with_contention(ContentionMode::Booked)
    .unwrap();
    let n = m.sub_accels.len();
    let gen = Gen::ranges(vec![(0, (1 << n) - 1), (0, n - 1)]);
    check("idle-regrant-monotone", 0x1D1E, 40, &gen, |v| {
        let s = v[1];
        let mut small: Vec<bool> = (0..n).map(|i| v[0] >> i & 1 == 1).collect();
        small[s] = true; // the queried unit is always busy
        let large = vec![true; n];
        let bw_small = m.contended_boundary_bw(s, &small);
        let bw_large = m.contended_boundary_bw(s, &large);
        for (j, (a, b)) in bw_small.iter().zip(&bw_large).enumerate() {
            if a + 1e-9 < *b {
                return Err(format!(
                    "unit {s} boundary {j}: busier set granted MORE ({b} > {a})"
                ));
            }
        }
        Ok(())
    });
}

/// Contention invariant #3: pinned capacity shares equal to the
/// proportional booking (which sums exactly to each shared node's
/// capacity) flatten to bit-identical specs — pinning is a superset of
/// the default policy, not a different model.
#[test]
fn pinned_shares_matching_proportional_split_are_identity() {
    for id in ["hier+xnode", "hier+xnode-cl"] {
        let class = HarpClass::from_id(id).unwrap();
        let m = MachineConfig::build(&class, &HardwareParams::default()).unwrap();
        let mut t = m.topology.clone();
        // Pin every unit that actually shares a node to its proportional
        // booking at that node.
        let users = t.node_users();
        for (n, us) in users.iter().enumerate() {
            if us.len() < 2 || t.nodes[n].size_words == u64::MAX {
                continue;
            }
            for (u, words) in t.booked_capacities(n, us) {
                t.accels[u].capacity_share = Some(words);
            }
        }
        assert!(
            t.accels.iter().any(|a| a.capacity_share.is_some()),
            "{id}: no shared node found — test is vacuous"
        );
        t.validate().unwrap();
        let prop = m.topology.flatten_all_with(ContentionMode::Booked);
        let pinned = t.flatten_all_with(ContentionMode::Booked);
        for (a, b) in prop.iter().zip(&pinned) {
            assert_eq!(a.levels.len(), b.levels.len());
            for (x, y) in a.levels.iter().zip(&b.levels) {
                assert_eq!(x.size_words, y.size_words, "{id}: pinned ≠ proportional");
                assert_eq!(x.bw_words_per_cycle, y.bw_words_per_cycle);
            }
        }
    }
}

/// Contention invariant #4: populating capacity shares is invisible to
/// classification — `classify(generate(c)) == c` still holds for every
/// taxonomy point with every attachment's share pinned.
#[test]
fn prop_round_trip_holds_with_shares_populated() {
    let points = HarpClass::all_points();
    let gen = Gen::ranges(vec![(0, points.len() - 1), (256, 4096)]);
    check("round-trip-with-shares", 0x5A5E, 30, &gen, |v| {
        let class = &points[v[0]];
        let params = HardwareParams {
            total_macs: (v[1] as u64) * 16,
            ..HardwareParams::default()
        };
        let m = MachineConfig::build(class, &params)?;
        let mut t = m.topology.clone();
        let users = t.node_users();
        for (n, us) in users.iter().enumerate() {
            if us.len() < 2 || t.nodes[n].size_words == u64::MAX {
                continue;
            }
            for (u, words) in t.booked_capacities(n, us) {
                t.accels[u].capacity_share = Some(words);
            }
        }
        t.validate()?;
        let back = t.classify()?;
        if back != *class {
            return Err(format!("{class} with shares classified as {back}"));
        }
        Ok(())
    });
}

/// Allocation: every op lands on a unit whose role accepts its class.
#[test]
fn prop_allocator_respects_roles() {
    let machine = MachineConfig::build(
        &HarpClass::from_id("hier+xdepth").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let classifier = Classifier::new(HardwareParams::default().tipping_ai());
    let gen = Gen::ranges(vec![(1, 512), (1, 512), (1, 512)]);
    check("allocator-roles", 0xE5, 30, &gen, |v| {
        let mut g = Cascade::new("a");
        g.push(TensorOp::gemm("x", Phase::Encoder, v[0] as u64, v[1] as u64, v[2] as u64));
        g.push(TensorOp::gemm("d", Phase::Decode, v[0] as u64, v[1] as u64, v[2] as u64));
        g.push(TensorOp::gemm("p", Phase::Prefill, v[0] as u64, v[1] as u64, v[2] as u64));
        let a = harp::hhp::allocator::allocate(&g, &machine, &classifier);
        for (i, &sub) in a.iter().enumerate() {
            let class = classifier.classify(&g.ops[i]);
            if !machine.sub_accels[sub].role.accepts(class) {
                return Err(format!("op {i} ({class:?}) on wrong unit {sub}"));
            }
        }
        Ok(())
    });
}

/// JSON: round-trip over randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    let gen = Gen::ranges(vec![(0, u32::MAX as usize)]);
    check("json-roundtrip", 0xF6, 100, &gen, |v| {
        let mut rng = Rng::new(v[0] as u64 + 1);
        let doc = random_json(&mut rng, 3);
        let text = doc.to_string_pretty();
        let re = Json::parse(&text).map_err(|e| e.to_string())?;
        if re != doc {
            return Err(format!("round-trip mismatch for {text}"));
        }
        Ok(())
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_below(1_000_000) as f64) / 8.0),
        3 => {
            let n = rng.next_below(8);
            Json::Str((0..n).map(|_| char::from(b'a' + rng.next_below(26) as u8)).collect())
        }
        4 => Json::Arr((0..rng.next_below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.next_below(4))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Cascade: merging preserves validity and totals.
#[test]
fn prop_cascade_merge() {
    let gen = Gen::ranges(vec![(1, 10), (1, 10)]);
    check("cascade-merge", 0x17, 30, &gen, |v| {
        let mk = |n: usize, tag: &str| {
            let mut g = Cascade::new(tag);
            for i in 0..n {
                g.push(TensorOp::gemm(&format!("{tag}{i}"), Phase::Encoder, 4, 4, 4));
                if i > 0 {
                    g.dep(i - 1, i);
                }
            }
            g
        };
        let mut a = mk(v[0], "a");
        let b = mk(v[1], "b");
        let macs = a.total_macs() + b.total_macs();
        a.merge(&b);
        a.validate()?;
        if a.total_macs() != macs {
            return Err("MACs not conserved by merge".into());
        }
        if a.ops.len() != v[0] + v[1] {
            return Err("ops lost".into());
        }
        Ok(())
    });
}
