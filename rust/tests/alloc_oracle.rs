//! Allocation-oracle suite: the `search` policy is squeezed between
//! its greedy starting point and the brute-force enumerated optimum on
//! machines small enough to enumerate (≤ 6 ops × ≤ 3 units), and every
//! policy is property-tested for assignment validity on random
//! cascades across ALL 16 taxonomy points. The determinism half pins
//! each policy's full stats document across worker counts, and the
//! replay-mode pin holds the incremental (`replay_delta`) search
//! trajectory byte-identical to the historical full-replay one.

use harp::arch::partition::{HardwareParams, MachineConfig};
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::hhp::allocator::{
    allocate, allocate_policy, eligible_units, search_allocation, AllocPolicy,
};
use harp::hhp::scheduler::{schedule, ScheduleOptions, ScheduleOracle};
use harp::mapper::blackbox::BlackboxMapper;
use harp::mapper::search::SearchBudget;
use harp::model::stats::OpStats;
use harp::util::rng::Rng;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};
use harp::workload::intensity::Classifier;

/// A random DAG of ≤ `n` small GEMMs with mixed phases (so both reuse
/// classes appear) and random forward edges.
fn random_cascade(rng: &mut Rng, n: usize) -> Cascade {
    let mut g = Cascade::new("oracle");
    for i in 0..n {
        let phase = match rng.next_below(3) {
            0 => Phase::Decode,
            1 => Phase::Prefill,
            _ => Phase::Encoder,
        };
        let m = 1u64 << rng.next_below(7);
        let nn = 8u64 << rng.next_below(5);
        let k = 8u64 << rng.next_below(5);
        g.push(TensorOp::gemm(&format!("o{i}"), phase, m, nn, k));
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < 0.35 {
                g.dep(i, j);
            }
        }
    }
    g
}

/// Cartesian product of the per-op eligible sets.
fn enumerate_assignments(eligible: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![Vec::new()];
    for options in eligible {
        let mut next = Vec::with_capacity(out.len() * options.len());
        for prefix in &out {
            for &u in options {
                let mut a = prefix.clone();
                a.push(u);
                next.push(a);
            }
        }
        out = next;
    }
    out
}

/// The oracle contract: on every enumerable case,
/// `optimum ≤ search ≤ greedy` — the local search never loses to its
/// starting point and never claims to beat the exhaustive optimum. The
/// makespans are measured through the REAL `schedule()` on the mapped
/// ops each policy hands back, so the bound holds for what evaluations
/// actually report, not just for the oracle's internal replays.
#[test]
fn search_bounded_by_greedy_and_enumerated_optimum() {
    let budget = SearchBudget { samples: 6, seed: 0xA110C };
    let mapper = BlackboxMapper { budget, threads: 2, ..BlackboxMapper::default() };
    // leaf+xnode is the degenerate case (one eligible unit per class —
    // search must equal greedy must equal the optimum); hier+xnode has
    // two IDENTICAL low units (symmetric choices); hier+compound has
    // two DIFFERENT low architectures, where the optimum genuinely
    // depends on which op lands where.
    for machine_id in ["leaf+xnode", "hier+xnode", "hier+compound"] {
        let machine = MachineConfig::build(
            &HarpClass::from_id(machine_id).unwrap(),
            &HardwareParams::default(),
        )
        .unwrap();
        assert!(machine.sub_accels.len() <= 3);
        let classifier = Classifier::new(machine.params.tipping_ai());
        let mut rng = Rng::new(0x0_2ACE ^ machine.sub_accels.len() as u64);
        for case in 0..4 {
            let g = random_cascade(&mut rng, 3 + rng.next_below(4)); // 3..=6 ops
            let eligible: Vec<Vec<usize>> = g
                .ops
                .iter()
                .map(|op| eligible_units(&machine, classifier.classify(op)))
                .collect();
            let costs = mapper.map_units(&g, &machine, &eligible);
            for dynamic_bw in [false, true] {
                let opts = ScheduleOptions { dynamic_bw };

                // Brute-force optimum over every eligible assignment.
                let mut oracle = ScheduleOracle::new(&g, &machine, &opts);
                let mut optimum = f64::INFINITY;
                let all = enumerate_assignments(&eligible);
                assert!(!all.is_empty() && all.len() <= 3usize.pow(6));
                for assignment in &all {
                    let stats: Vec<&OpStats> = assignment
                        .iter()
                        .enumerate()
                        .map(|(i, &u)| &costs[i][u].as_ref().unwrap().stats)
                        .collect();
                    optimum = optimum.min(oracle.replay(assignment, &stats));
                }

                // Greedy through the real pipeline.
                let greedy = allocate(&g, &machine, &classifier);
                let greedy_mapped = mapper.map_cascade(&g, &machine, &greedy);
                let greedy_makespan = schedule(&g, &machine, &greedy_mapped, &opts).makespan;

                // Search through the real pipeline.
                let (_, searched_mapped) =
                    search_allocation(&g, &machine, &classifier, &mapper, &opts);
                let searched = schedule(&g, &machine, &searched_mapped, &opts).makespan;

                let eps = 1e-9 * greedy_makespan.max(1.0);
                assert!(
                    searched <= greedy_makespan + eps,
                    "{machine_id} case {case} dyn={dynamic_bw}: search {searched} \
                     worse than greedy {greedy_makespan}"
                );
                assert!(
                    searched >= optimum - eps,
                    "{machine_id} case {case} dyn={dynamic_bw}: search {searched} \
                     below the enumerated optimum {optimum}"
                );
                assert!(
                    optimum <= greedy_makespan + eps,
                    "{machine_id} case {case}: greedy {greedy_makespan} below the \
                     optimum {optimum} — the enumeration is broken"
                );
            }
        }
    }
}

/// Validity property over the WHOLE taxonomy: on every one of the 16
/// generatable points, every policy assigns every op of a random
/// cascade to a unit whose role accepts the op's reuse class (with the
/// homogeneous fallback intact — when no unit accepts a class, any
/// unit is eligible).
#[test]
fn every_policy_yields_valid_assignments_on_all_taxonomy_points() {
    let params = HardwareParams::default();
    let mapper =
        BlackboxMapper {
            budget: SearchBudget { samples: 4, seed: 0x7E57 },
            threads: 2,
            ..BlackboxMapper::default()
        };
    for class in HarpClass::all_points() {
        let machine = MachineConfig::build(&class, &params).unwrap();
        let classifier = Classifier::new(machine.params.tipping_ai());
        let mut rng = Rng::new(0xFACE ^ machine.sub_accels.len() as u64);
        for _ in 0..2 {
            let g = random_cascade(&mut rng, 3 + rng.next_below(4));
            let check = |assignment: &[usize], policy: &str| {
                assert_eq!(assignment.len(), g.ops.len(), "{class}/{policy}");
                for (i, &u) in assignment.iter().enumerate() {
                    let cl = classifier.classify(&g.ops[i]);
                    assert!(
                        eligible_units(&machine, cl).contains(&u),
                        "{class}/{policy}: op {i} ({cl:?}) on ineligible unit {u}"
                    );
                }
            };
            for p in [AllocPolicy::Greedy, AllocPolicy::RoundRobin, AllocPolicy::CriticalPath]
            {
                check(&allocate_policy(p, &g, &machine, &classifier), p.name());
            }
            let (a, mapped) = search_allocation(
                &g,
                &machine,
                &classifier,
                &mapper,
                &ScheduleOptions::default(),
            );
            check(&a, "search");
            for (i, mo) in mapped.iter().enumerate() {
                assert_eq!(mo.sub_accel, a[i], "{class}: mapped ops disagree");
            }
        }
    }
}

/// Regression pin for the incremental-replay rewrite: running the
/// `search` policy with `replay_delta` probes (the default) and with
/// the historical full `replay` on every probe must walk the SAME
/// trajectory — same final assignment, and a byte-identical stats
/// document once the winner goes through the real `schedule()`. Any
/// divergence means an incremental probe returned a different makespan
/// bit pattern somewhere, flipping an accept/reject decision.
#[test]
fn incremental_and_full_replay_search_walk_identical_trajectories() {
    use harp::arch::topology::ContentionMode;
    use harp::hhp::allocator::search_allocation_impl;
    use harp::hhp::stats::CascadeStats;

    let mapper = BlackboxMapper {
        budget: SearchBudget { samples: 6, seed: 0xDE17A },
        threads: 2,
        ..BlackboxMapper::default()
    };
    // hier+xnode exercises symmetric unit choices; hier+compound makes
    // the moves matter; Booked adds capacity slices + shared-edge
    // arbitration to the replayed event loop.
    for (machine_id, contention) in [
        ("hier+xnode", ContentionMode::Off),
        ("hier+compound", ContentionMode::Off),
        ("hier+compound", ContentionMode::Booked),
    ] {
        let machine = MachineConfig::build(
            &HarpClass::from_id(machine_id).unwrap(),
            &HardwareParams::default(),
        )
        .unwrap()
        .with_contention(contention)
        .unwrap();
        let classifier = Classifier::new(machine.params.tipping_ai());
        let mut rng = Rng::new(0x1DE_17A);
        for case in 0..3 {
            let g = random_cascade(&mut rng, 5 + rng.next_below(4)); // 5..=8 ops
            for dynamic_bw in [false, true] {
                let opts = ScheduleOptions { dynamic_bw };
                let run = |incremental: bool| {
                    let (a, mapped) = search_allocation_impl(
                        &g, &machine, &classifier, &mapper, &opts, incremental,
                    );
                    let sched = schedule(&g, &machine, &mapped, &opts);
                    let stats = CascadeStats::aggregate(
                        &g,
                        &machine,
                        &mapped,
                        &sched,
                        AllocPolicy::Search,
                    );
                    (a, stats.to_json().to_string_pretty())
                };
                let (a_inc, doc_inc) = run(true);
                let (a_full, doc_full) = run(false);
                assert_eq!(
                    a_inc, a_full,
                    "{machine_id}/{contention:?} case {case} dyn={dynamic_bw}: \
                     incremental and full replay searched different assignments"
                );
                assert_eq!(
                    doc_inc, doc_full,
                    "{machine_id}/{contention:?} case {case} dyn={dynamic_bw}: \
                     stats documents diverge between replay modes"
                );
            }
        }
    }
}

/// Determinism: every policy's full stats document is bit-identical
/// across worker counts — the parallel cost-matrix fan-out and the
/// serial local search cannot let `HARP_THREADS` leak into results.
#[test]
fn every_policy_bit_identical_across_thread_counts() {
    let g = harp::workload::transformer::decoder_cascade(
        &harp::workload::transformer::llama2(),
    );
    let class = HarpClass::from_id("hier+xnode").unwrap();
    for policy in AllocPolicy::ALL {
        let run = |threads: usize| {
            let mut opts = EvalOptions { samples: 8, ..EvalOptions::default() };
            opts.alloc = policy;
            opts.threads = threads;
            evaluate_cascade_on_config(&class, &HardwareParams::default(), &g, &opts)
                .unwrap()
                .stats
                .to_json()
                .to_string_pretty()
        };
        let serial = run(1);
        for threads in [2usize, 4] {
            assert_eq!(
                serial,
                run(threads),
                "{}: stats differ between 1 and {threads} threads",
                policy.name()
            );
        }
    }
}
