//! Differential suite for incremental schedule replay.
//!
//! `ScheduleOracle::replay_delta` promises bit-identity with the full
//! `replay` — and with `schedule().makespan` — under its caller
//! contract (stats are a pure function of `(op, unit)`). This suite
//! drives seeded random single-op-move sequences over random DAGs on
//! ALL 16 taxonomy points, crossed with {static, dynamic bandwidth}
//! and {contention off, booked}, asserting the three paths agree
//! bitwise at EVERY step — makespans and the per-op delay/latency
//! buffers the allocation search ranks its moves by. Targeted cases
//! pin the boundary behaviour: repeated replays on one oracle (the
//! no-change fast path), a critical-path move (which must fall back to
//! a full replay), a move that empties a unit's queue, and a leaf move
//! that provably takes the mechanical-prefix path.

use harp::arch::partition::{HardwareParams, MachineConfig};
use harp::arch::spec::ArchSpec;
use harp::arch::taxonomy::HarpClass;
use harp::arch::topology::ContentionMode;
use harp::hhp::scheduler::{schedule, ScheduleOptions, ScheduleOracle};
use harp::mapper::blackbox::MappedOp;
use harp::model::stats::OpStats;
use harp::util::rng::Rng;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};

/// Random DAG of `n` ops with forward edges at probability `edge_p`.
fn random_cascade(rng: &mut Rng, n: usize, edge_p: f64) -> Cascade {
    let mut g = Cascade::new("delta");
    for i in 0..n {
        g.push(TensorOp::gemm(&format!("o{i}"), Phase::Encoder, 8, 8, 8));
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < edge_p {
                g.dep(i, j);
            }
        }
    }
    g
}

/// Synthetic per-(op, unit) stats, shaped to the unit's spec: one
/// boundary per level pair (so the booked-contention grant vector
/// lines up), words scaled to the spec bandwidths so the dynamic
/// re-grant genuinely moves latencies, and small-integer compute
/// cycles so priority ties (the `max_by` order-sensitivity) occur.
fn synth_stats(rng: &mut Rng, spec: &ArchSpec) -> OpStats {
    let mut s = OpStats::new_empty();
    s.compute_cycles = (1 + rng.next_below(12)) as f64;
    let nb = spec.levels.len() - 1;
    let mut worst = s.compute_cycles;
    for j in 0..nb {
        let bw = spec.levels[j + 1].bw_words_per_cycle;
        let words = bw * (1 + rng.next_below(20)) as f64;
        s.boundary_words.push((spec.levels[j + 1].kind, words));
        worst = worst.max(words / bw);
    }
    s.cycles = worst;
    let mut onchip = s.compute_cycles;
    for j in 0..nb.saturating_sub(1) {
        let bw = spec.levels[j + 1].bw_words_per_cycle;
        onchip = onchip.max(s.boundary_words[j].1 / bw);
    }
    s.onchip_bound_cycles = onchip;
    s
}

/// The fixed cost matrix: `replay_delta`'s pure-function contract holds
/// by construction, exactly as in the allocation search.
fn cost_matrix(rng: &mut Rng, n: usize, machine: &MachineConfig) -> Vec<Vec<OpStats>> {
    (0..n)
        .map(|_| machine.sub_accels.iter().map(|su| synth_stats(rng, &su.spec)).collect())
        .collect()
}

fn stats_view<'a>(costs: &'a [Vec<OpStats>], assignment: &[usize]) -> Vec<&'a OpStats> {
    assignment.iter().enumerate().map(|(i, &u)| &costs[i][u]).collect()
}

fn mapped_view(costs: &[Vec<OpStats>], assignment: &[usize]) -> Vec<MappedOp> {
    assignment
        .iter()
        .enumerate()
        .map(|(i, &u)| MappedOp {
            op_index: i,
            sub_accel: u,
            stats: costs[i][u].clone(),
            evaluated: 0,
        })
        .collect()
}

fn assert_bits_eq(a: f64, b: f64, ctx: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: {a} vs {b}");
}

fn assert_slice_bits_eq(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: index {k}: {x} vs {y}");
    }
}

/// The headline differential: on every taxonomy point × bandwidth mode
/// × contention mode, a seeded sequence of random single-op moves keeps
/// `replay_delta` == `replay` == `schedule().makespan` bit-exactly at
/// every step, including the delay/latency buffers both oracles expose.
#[test]
fn incremental_replay_matches_full_and_schedule_on_all_taxonomy_points() {
    let params = HardwareParams::default();
    let mut total_incremental = 0usize;
    for (ci, class) in HarpClass::all_points().into_iter().enumerate() {
        for contention in [ContentionMode::Off, ContentionMode::Booked] {
            let machine = MachineConfig::build(&class, &params)
                .unwrap()
                .with_contention(contention)
                .unwrap();
            let nsub = machine.sub_accels.len();
            let mut rng = Rng::new(0xD1FF_0000 ^ (ci as u64) << 1 ^ (contention == ContentionMode::Booked) as u64);
            for dynamic_bw in [false, true] {
                let opts = ScheduleOptions { dynamic_bw };
                let n = 8 + rng.next_below(5);
                let g = random_cascade(&mut rng, n, 0.3);
                let costs = cost_matrix(&mut rng, n, &machine);
                let mut assignment: Vec<usize> =
                    (0..n).map(|_| rng.next_below(nsub)).collect();
                let mut inc = ScheduleOracle::new(&g, &machine, &opts);
                let mut full = ScheduleOracle::new(&g, &machine, &opts);
                for step in 0..10 {
                    if step > 0 && nsub > 1 {
                        let i = rng.next_below(n);
                        let u = rng.next_below(nsub);
                        assignment[i] =
                            if u == assignment[i] { (u + 1) % nsub } else { u };
                    }
                    let view = stats_view(&costs, &assignment);
                    let m_inc = inc.replay_delta(&assignment, &view);
                    let m_full = full.replay(&assignment, &view);
                    let m_sched =
                        schedule(&g, &machine, &mapped_view(&costs, &assignment), &opts)
                            .makespan;
                    let ctx = format!(
                        "{} {contention:?} dyn={dynamic_bw} step {step}",
                        class.id()
                    );
                    assert_bits_eq(m_inc, m_full, &format!("{ctx}: delta vs full"));
                    assert_bits_eq(m_full, m_sched, &format!("{ctx}: full vs schedule"));
                    assert_slice_bits_eq(
                        inc.queue_delays(),
                        full.queue_delays(),
                        &format!("{ctx}: queue delays"),
                    );
                    assert_slice_bits_eq(
                        inc.latencies(),
                        full.latencies(),
                        &format!("{ctx}: latencies"),
                    );
                }
                total_incremental += inc.replay_counts().1;
            }
        }
    }
    // The sweep must actually exercise the incremental machinery, not
    // degenerate into wall-to-wall fallbacks.
    assert!(total_incremental > 0, "no incremental replay ever ran");
}

/// Repeated replays of the SAME assignment on one oracle: the first
/// call is the baseline full replay, every later one takes the
/// no-change fast path and returns the identical makespan bits.
#[test]
fn repeated_replays_take_the_fast_path() {
    let machine = MachineConfig::build(
        &HarpClass::from_id("hier+xnode").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let mut rng = Rng::new(0xFA57);
    let g = random_cascade(&mut rng, 9, 0.35);
    let costs = cost_matrix(&mut rng, 9, &machine);
    let assignment: Vec<usize> =
        (0..9).map(|_| rng.next_below(machine.sub_accels.len())).collect();
    let view = stats_view(&costs, &assignment);
    for dynamic_bw in [false, true] {
        let opts = ScheduleOptions { dynamic_bw };
        let mut oracle = ScheduleOracle::new(&g, &machine, &opts);
        let first = oracle.replay_delta(&assignment, &view);
        let second = oracle.replay_delta(&assignment, &view);
        let third = oracle.replay_delta(&assignment, &view);
        assert_bits_eq(first, second, "second replay");
        assert_bits_eq(first, third, "third replay");
        assert_eq!(
            oracle.replay_counts(),
            (1, 2),
            "one baseline full replay, two fast-path hits"
        );
    }
}

/// A move on the critical path dirties a source op (the priority change
/// propagates all the way up), so there is no reusable prefix: the
/// oracle must fall back to a full replay — and still agree with
/// `schedule()` bitwise.
#[test]
fn critical_path_move_falls_back_to_full_replay() {
    let machine = MachineConfig::build(
        &HarpClass::from_id("hier+xnode").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let nsub = machine.sub_accels.len();
    assert!(nsub >= 2);
    // A pure chain: every op is on the critical path.
    let mut g = Cascade::new("chain");
    for i in 0..6 {
        g.push(TensorOp::gemm(&format!("c{i}"), Phase::Encoder, 8, 8, 8));
    }
    for i in 0..5 {
        g.dep(i, i + 1);
    }
    // Distinct cycles per (op, unit), so any move provably shifts the
    // moved op's latency — and with it every ancestor's priority.
    let costs: Vec<Vec<OpStats>> = (0..6)
        .map(|i| {
            (0..nsub)
                .map(|u| {
                    let mut s = OpStats::new_empty();
                    s.cycles = (10 + i * 17 + u * 5) as f64;
                    s.compute_cycles = s.cycles;
                    s.onchip_bound_cycles = s.cycles;
                    s
                })
                .collect()
        })
        .collect();
    let opts = ScheduleOptions { dynamic_bw: true };
    let mut oracle = ScheduleOracle::new(&g, &machine, &opts);
    let mut assignment = vec![0usize; 6];
    oracle.replay_delta(&assignment, &stats_view(&costs, &assignment));
    assert_eq!(oracle.replay_counts(), (1, 0));
    // Move a mid-chain op: its latency change shifts its own priority,
    // which propagates through every ancestor to the source.
    assignment[3] = 1;
    let m = oracle.replay_delta(&assignment, &stats_view(&costs, &assignment));
    assert_eq!(
        oracle.replay_counts().0,
        2,
        "critical-path move must fall back to a full replay"
    );
    let m_sched = schedule(&g, &machine, &mapped_view(&costs, &assignment), &opts).makespan;
    assert_bits_eq(m, m_sched, "fallback vs schedule");
}

/// Boundary cases around unit queues on a wide spine-and-leaves DAG:
/// a late-leaf move has a provable reusable prefix (its priority change
/// does not propagate past its predecessor, whose other successor
/// dominates), and a move that empties a unit's queue entirely stays
/// bit-identical too.
#[test]
fn leaf_moves_use_the_prefix_and_emptying_a_queue_stays_exact() {
    let machine = MachineConfig::build(
        &HarpClass::from_id("hier+xnode").unwrap(),
        &HardwareParams::default(),
    )
    .unwrap();
    let nsub = machine.sub_accels.len();
    assert!(nsub >= 2);
    // Spine 0→1→2→3 of heavy ops; leaves 4..7 hang off op 1. The spine
    // dominates every priority, so leaf moves never dirty it.
    let mut g = Cascade::new("spine");
    for i in 0..8 {
        g.push(TensorOp::gemm(&format!("s{i}"), Phase::Encoder, 8, 8, 8));
    }
    for i in 0..3 {
        g.dep(i, i + 1);
    }
    for leaf in 4..8 {
        g.dep(1, leaf);
    }
    // Hand-built stats: spine ops cost 1000 on any unit, leaves 3..10 —
    // far below the downstream spine priority at their predecessor.
    let mut costs: Vec<Vec<OpStats>> = Vec::new();
    for i in 0..8 {
        let mut row = Vec::new();
        for u in 0..nsub {
            let mut s = OpStats::new_empty();
            s.cycles = if i < 4 { 1000.0 } else { (3 + i + u) as f64 };
            s.compute_cycles = s.cycles;
            s.onchip_bound_cycles = s.cycles;
            row.push(s);
        }
        costs.push(row);
    }
    let opts = ScheduleOptions::default();
    let mut oracle = ScheduleOracle::new(&g, &machine, &opts);
    // Spine on unit 0, leaves on unit 1.
    let mut assignment = vec![0, 0, 0, 0, 1, 1, 1, 1];
    let check = |oracle: &mut ScheduleOracle, assignment: &[usize], ctx: &str| {
        let m = oracle.replay_delta(assignment, &stats_view(&costs, assignment));
        let m_sched =
            schedule(&g, &machine, &mapped_view(&costs, assignment), &opts).makespan;
        assert_bits_eq(m, m_sched, ctx);
    };
    check(&mut oracle, &assignment, "baseline");
    assert_eq!(oracle.replay_counts(), (1, 0));

    // Late-leaf move: ready only once op 1 completes (t = 2000 > 0), and
    // its priority change stays below the spine's — the mechanical
    // prefix must carry it, with no full-replay fallback.
    assignment[6] = 0;
    check(&mut oracle, &assignment, "leaf move");
    assert_eq!(
        oracle.replay_counts(),
        (1, 1),
        "a late-leaf move must replay incrementally, not fall back"
    );

    // Empty unit 1's queue completely: every leaf back on unit 0.
    assignment = vec![0; 8];
    check(&mut oracle, &assignment, "queue emptied");
    // And repopulate it from empty.
    assignment[5] = 1;
    check(&mut oracle, &assignment, "queue repopulated");
    let (_, incremental) = oracle.replay_counts();
    assert!(incremental >= 1);
}
