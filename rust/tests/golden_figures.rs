//! Golden regression + determinism tests for the figure drivers.
//!
//! Each driver is rendered at a small fixed budget and compared
//! byte-for-byte against `tests/goldens/<name>.txt`:
//!
//! - A missing golden is written on first run (bootstrap) — the test
//!   passes and later runs regress against it.
//! - Intentional output changes are recorded by re-running with
//!   `HARP_UPDATE_GOLDENS=1` (update-on-intent).
//!
//! Independent of the snapshots, the figure text must be byte-identical
//! across worker counts — the parallel sweep engine's core guarantee —
//! which `fig10_byte_identical_across_thread_counts` asserts by running
//! the same driver against single- and multi-threaded evaluators.

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::arch::topology::ContentionMode;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::coordinator::figures::{self, Evaluator};
use harp::util::threadpool::default_threads;
use harp::workload::registry;
use harp::workload::transformer;
use std::path::PathBuf;

/// The small fixed budget all goldens are rendered at.
fn golden_opts(threads: usize) -> EvalOptions {
    let mut o = EvalOptions { samples: 12, ..EvalOptions::default() };
    o.seed = 0xD00D_FEED;
    o.threads = threads;
    o
}

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("goldens")
}

fn assert_golden(name: &str, rendered: &str) {
    let dir = goldens_dir();
    std::fs::create_dir_all(&dir).expect("create goldens dir");
    let path = dir.join(format!("{name}.txt"));
    let update = std::env::var("HARP_UPDATE_GOLDENS").map(|v| v == "1").unwrap_or(false);
    if update || !path.exists() {
        // Bootstrapping on a fresh checkout provides no regression
        // protection for THIS run — it only arms later ones. CI (or any
        // environment that expects committed goldens) should set
        // HARP_REQUIRE_GOLDENS=1 to turn a missing snapshot into a
        // failure instead of a silent vacuous pass.
        let require =
            std::env::var("HARP_REQUIRE_GOLDENS").map(|v| v == "1").unwrap_or(false);
        assert!(
            update || !require,
            "golden '{name}' missing at {} and HARP_REQUIRE_GOLDENS=1 — \
             generate and commit it (run once with HARP_UPDATE_GOLDENS=1)",
            path.display()
        );
        std::fs::write(&path, rendered).expect("write golden");
        eprintln!(
            "golden '{name}': wrote {} ({})",
            path.display(),
            if update { "HARP_UPDATE_GOLDENS=1" } else { "bootstrap" }
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert!(
        rendered == want,
        "golden '{name}' drifted from {} — rerun with HARP_UPDATE_GOLDENS=1 if intended\n\
         --- got ---\n{rendered}\n--- want ---\n{want}",
        path.display()
    );
}

#[test]
fn golden_table1() {
    assert_golden("table1", &figures::table1());
}

/// The workload registry's Table II-style summary (the `harp workload
/// list` body): pins the registered names, cascade sizes, MAC counts,
/// and intensity spans of every family — a generator change that moves
/// ANY built-in's shape shows up here.
#[test]
fn golden_workload_table() {
    assert_golden("workload_table", &figures::workload_table());
}

/// Fig 6-style speedup sweep over one NEW family (MoE decode): the
/// workload front-end's analog of the paper-figure goldens. The paper's
/// own fig6 golden is untouched — this pins the new family's numbers.
#[test]
fn golden_fig6_moe_decode() {
    let ev = Evaluator::new(golden_opts(default_threads()));
    let wl = registry::by_name("moe_decode").expect("registered");
    assert_golden("fig6_moe_decode", &figures::fig6_style_speedup(&ev, &wl).render());
}

#[test]
fn golden_fig6_and_fig7() {
    // One evaluator shared by both drivers: fig7's points are a subset
    // of fig6's, so the cross-driver cache makes the second render free.
    let ev = Evaluator::new(golden_opts(default_threads()));
    let (fig, zoom) = figures::fig6_speedup(&ev);
    assert_golden("fig6_speedup", &format!("{}\n{}", fig.render(), zoom.render()));
    let fig7: Vec<String> = figures::fig7_energy(&ev).iter().map(|f| f.render()).collect();
    assert_golden("fig7_energy", &fig7.join("\n"));
}

/// Allocation-policy ablation: pins every policy's speedup-over-greedy
/// on the policy × taxonomy-point × (Table II + MoE) grid. The greedy
/// column is definitionally 1.0 — a drift there means the baseline
/// itself moved; the search column must never fall below 1.0 (asserted
/// structurally here, independent of the snapshot).
#[test]
fn golden_fig_alloc_ablation() {
    let ev = Evaluator::new(golden_opts(default_threads()));
    let fig = figures::fig_alloc_ablation(&ev);
    let rendered = fig.render();
    let greedy = fig.series.iter().find(|s| s.name == "greedy").expect("greedy series");
    for (label, v) in &greedy.rows {
        assert!((v - 1.0).abs() < 1e-9, "greedy baseline moved at {label}: {v}");
    }
    let search = fig.series.iter().find(|s| s.name == "search").expect("search series");
    for (label, v) in &search.rows {
        assert!(*v >= 1.0 - 1e-9, "search below greedy at {label}: {v}");
    }
    assert_golden("fig_alloc_ablation", &rendered);
}

#[test]
fn golden_fig8_and_fig9() {
    // One evaluator shared by both drivers: fig8's points are a subset
    // of fig6's grid and fig9 adds the batch-1 decoder operating points,
    // so sharing maximises cross-driver cache hits.
    let ev = Evaluator::new(golden_opts(default_threads()));
    assert_golden("fig8_mults_per_joule", &figures::fig8_mults_per_joule(&ev).render());
    assert_golden("fig9_subaccel_energy", &figures::fig9_subaccel_energy(&ev).render());
}

/// Contention-on goldens for the shared-node taxonomy points. The
/// existing fig6/7/10 goldens pin `contention: off` (the figure drivers'
/// default, byte-identical to the pre-contention model); these pin the
/// `Booked` numbers for the two machines where booking actually changes
/// the map space — hier+xnode (two low units on one LLB) and the
/// clustered hierarchical point (a shared LLB per cluster).
fn render_contention_eval(class_id: &str) -> String {
    let class = HarpClass::from_id(class_id).expect("taxonomy id");
    let mut opts = golden_opts(default_threads());
    opts.contention = ContentionMode::Booked;
    let cascade = transformer::cascade_for(&transformer::llama2());
    let r =
        evaluate_cascade_on_config(&class, &HardwareParams::default(), &cascade, &opts)
            .expect("valid eval point");
    // Machine description (shows the booked capacity slices) plus the
    // full deterministic stats document.
    format!("{}\n{}\n", r.machine.describe(), r.stats.to_json().to_string_pretty())
}

#[test]
fn golden_contention_hier_xnode() {
    assert_golden("contention_hier_xnode", &render_contention_eval("hier+xnode"));
}

#[test]
fn golden_contention_clustered() {
    assert_golden("contention_clustered", &render_contention_eval("hier+xnode-cl"));
}

/// The back-compat half of the contention contract, independent of any
/// committed file: a shared-node machine round-tripped through
/// `Booked` and back to `Off` evaluates bit-identically to one that
/// was never re-flattened at all — so `contention: "off"` reproduces
/// the legacy numbers and the existing fig6/7/10 goldens stay valid.
#[test]
fn contention_off_is_bit_identical_to_legacy_path() {
    use harp::arch::partition::MachineConfig;
    use harp::coordinator::experiment::evaluate_cascade_on_machine;
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let cascade = transformer::cascade_for(&transformer::llama2());
    let opts = golden_opts(1);
    let pristine = MachineConfig::build(&class, &HardwareParams::default()).unwrap();
    let round_tripped = pristine
        .clone()
        .with_contention(ContentionMode::Booked)
        .unwrap()
        .with_contention(ContentionMode::Off)
        .unwrap();
    let a = evaluate_cascade_on_machine(&pristine, &cascade, &opts).unwrap();
    let b = evaluate_cascade_on_machine(&round_tripped, &cascade, &opts).unwrap();
    assert_eq!(
        a.stats.to_json().to_string_pretty(),
        b.stats.to_json().to_string_pretty()
    );
    // And Booked genuinely moves the machine's inputs on this point, so
    // the equality above is not vacuous.
    let booked = pristine.with_contention(ContentionMode::Booked).unwrap();
    assert_ne!(
        booked.sub_accels[1].spec.levels[2].size_words,
        round_tripped.sub_accels[1].spec.levels[2].size_words
    );
}

/// Serving saturation-knee figure: goodput vs offered load per
/// taxonomy point over a fixed seeded stream, plus the detected knee.
/// Structural invariants hold independent of the snapshot: goodput is
/// non-negative everywhere, and every knee row lands on the load grid.
#[test]
fn golden_fig_serving_knee() {
    let ev = Evaluator::new(golden_opts(default_threads()));
    let fig = figures::fig_serving_knee(&ev);
    for s in &fig.series {
        for (label, v) in &s.rows {
            assert!(*v >= 0.0, "negative value in {} at {label}: {v}", s.name);
            if label == "knee" {
                assert!(
                    figures::SERVING_LOAD_GRID.contains(v),
                    "knee of {} off the load grid: {v}",
                    s.name
                );
            }
        }
    }
    assert_golden("fig_serving_knee", &fig.render());
}

/// Per-class serving knee: the mixed-priority companion sweep. The
/// structural invariants mirror the aggregate figure's (non-negative
/// goodput, knees on the load grid), plus one figure-specific check:
/// every taxonomy point contributes exactly one interactive and one
/// batch series.
#[test]
fn golden_fig_serving_knee_class() {
    let ev = Evaluator::new(golden_opts(default_threads()));
    let fig = figures::fig_serving_knee_class(&ev);
    let interactive =
        fig.series.iter().filter(|s| s.name.ends_with("[interactive]")).count();
    let batch = fig.series.iter().filter(|s| s.name.ends_with("[batch]")).count();
    assert_eq!(interactive, batch, "one series per class per taxonomy point");
    assert_eq!(interactive + batch, fig.series.len());
    for s in &fig.series {
        for (label, v) in &s.rows {
            assert!(*v >= 0.0, "negative value in {} at {label}: {v}", s.name);
            if label == "knee" {
                assert!(
                    figures::SERVING_LOAD_GRID.contains(v),
                    "knee of {} off the load grid: {v}",
                    s.name
                );
            }
        }
    }
    assert_golden("fig_serving_knee_class", &fig.render());
}

/// Disaggregated-serving figure: co-located vs prefill/decode-split
/// goodput and TTFT per multi-type taxonomy point, plus the KV words
/// moved across the split. Structural invariants independent of the
/// snapshot: goodput and moved words are non-negative, the saturated
/// flag is boolean, every point contributes a [coloc]/[disagg] pair,
/// and single-type points (leaf+homo) contribute nothing.
#[test]
fn golden_fig_serving_disagg() {
    let ev = Evaluator::new(golden_opts(default_threads()));
    let fig = figures::fig_serving_disagg(&ev);
    let coloc = fig.series.iter().filter(|s| s.name.ends_with("[coloc]")).count();
    let disagg = fig.series.iter().filter(|s| s.name.ends_with("[disagg]")).count();
    assert_eq!(coloc, disagg, "one coloc/disagg pair per multi-type taxonomy point");
    assert_eq!(coloc + disagg, fig.series.len());
    assert!(
        !fig.series.iter().any(|s| s.name.contains("leaf+homo")),
        "single-type point leaked into the disagg figure"
    );
    for s in &fig.series {
        for (label, v) in &s.rows {
            assert!(*v >= 0.0, "negative value in {} at {label}: {v}", s.name);
            if label == "saturated" {
                assert!(*v == 0.0 || *v == 1.0, "non-boolean saturated flag: {v}");
            }
        }
    }
    assert_golden("fig_serving_disagg", &fig.render());
}

/// The serving engine's thread invariance: only the calibration probes
/// fan out across workers, so the whole figure must render
/// byte-identically for any worker count.
#[test]
fn fig_serving_knee_byte_identical_across_thread_counts() {
    let serial = figures::fig_serving_knee(&Evaluator::new(golden_opts(1))).render();
    let par = figures::fig_serving_knee(&Evaluator::new(golden_opts(4))).render();
    assert_eq!(
        serial, par,
        "serving figure must be byte-identical across worker counts"
    );
    let serial_c = figures::fig_serving_knee_class(&Evaluator::new(golden_opts(1))).render();
    let par_c = figures::fig_serving_knee_class(&Evaluator::new(golden_opts(4))).render();
    assert_eq!(
        serial_c, par_c,
        "per-class serving figure must be byte-identical across worker counts"
    );
}

#[test]
fn fig10_byte_identical_across_thread_counts() {
    let ev_serial = Evaluator::new(golden_opts(1));
    let serial = figures::fig10_bw_partition(&ev_serial).render();
    let ev_par = Evaluator::new(golden_opts(4));
    let par = figures::fig10_bw_partition(&ev_par).render();
    assert_eq!(
        serial, par,
        "figure output must be byte-identical across worker counts"
    );
    assert_golden("fig10_bw_partition", &serial);
}
