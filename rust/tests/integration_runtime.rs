//! Integration: the PJRT runtime against the built artifacts.
//!
//! Skips gracefully (with a notice) when `make artifacts` has not run —
//! `make test` always builds them first.

use harp::runtime::client::Runtime;
use harp::runtime::validate::validate_all;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn artifacts_validate_against_goldens() {
    let Some(dir) = artifacts_dir() else { return };
    let reports = validate_all(&dir).expect("load + run artifacts");
    assert_eq!(reports.len(), 4, "expected 4 artifacts");
    for r in &reports {
        assert!(
            r.ok,
            "{}: rel err {:.3e} vs golden",
            r.outcome.name, r.outcome.sum_rel_err
        );
    }
}

#[test]
fn runtime_exposes_manifest_metadata() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let names = rt.artifact_names();
    for expected in ["gemm", "attention", "encoder_layer", "decode_step"] {
        assert!(names.contains(&expected), "missing artifact {expected}");
    }
    let spec = rt.spec("encoder_layer").unwrap();
    assert_eq!(spec.inputs.len(), 7); // x + 6 weight matrices
    assert_eq!(spec.inputs[0].shape, vec![128, 256]);
}

#[test]
fn repeated_execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let a = rt.run("gemm").unwrap();
    let b = rt.run("gemm").unwrap();
    assert_eq!(a.output_sum, b.output_sum);
    assert_eq!(a.elements, b.elements);
}

#[test]
fn decode_step_artifact_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let out = rt.run("decode_step").unwrap();
    assert_eq!(out.elements, 256); // [1, d_model]
    assert!(out.passed());
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    assert!(rt.run("nope").is_err());
}
