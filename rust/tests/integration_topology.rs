//! Integration: explicit `--topology` machine trees through the full
//! evaluation pipeline, and the shipped example files.

use harp::arch::partition::MachineConfig;
use harp::arch::taxonomy::{ComputePlacement, HeterogeneityLoc};
use harp::arch::topology::{ContentionMode, MachineTopology};
use harp::coordinator::experiment::{evaluate_cascade_on_machine, EvalOptions};
use harp::util::json::Json;
use harp::workload::transformer;
use std::path::PathBuf;

const EXAMPLES: [&str; 6] = [
    "b100_intra_node.json",
    "herald_cross_node.json",
    "symphony_clustered.json",
    "neupim_cross_depth.json",
    "fig4h_compound.json",
    "hier_xnode_shared_llb.json",
];

fn load(name: &str) -> MachineTopology {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("topologies")
        .join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    MachineTopology::from_json(&Json::parse(&text).expect("valid JSON"))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Every shipped example classifies to the taxonomy row it illustrates.
#[test]
fn example_topologies_classify_to_their_rows() {
    let cases: [(&str, ComputePlacement, HeterogeneityLoc); 6] = [
        (
            "hier_xnode_shared_llb.json",
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::CrossNode { clustered: false },
        ),
        ("b100_intra_node.json", ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode),
        (
            "herald_cross_node.json",
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::CrossNode { clustered: false },
        ),
        (
            "symphony_clustered.json",
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::CrossNode { clustered: true },
        ),
        (
            "neupim_cross_depth.json",
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::CrossDepth,
        ),
        (
            "fig4h_compound.json",
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::CrossNode { clustered: false },
                HeterogeneityLoc::CrossDepth,
            ]),
        ),
    ];
    for (file, placement, het) in cases {
        let t = load(file);
        let c = t.classify().unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(c.placement, placement, "{file}");
        assert_eq!(c.heterogeneity, het, "{file}");
    }
}

/// Acceptance: a ≥3-sub-accelerator topology evaluates end-to-end, and
/// the scheduler's busy fractions are consistent with the makespan —
/// `busy_fraction[s] · makespan` sums to the total scheduled op time.
#[test]
fn three_accel_topology_evaluates_end_to_end() {
    let machine = MachineConfig::from_topology(load("fig4h_compound.json")).unwrap();
    assert!(machine.sub_accels.len() >= 3, "need ≥3 sub-accelerators");

    let wl = transformer::llama2();
    let cascade = transformer::cascade_for(&wl);
    let opts = EvalOptions { samples: 40, ..EvalOptions::default() };
    let r = evaluate_cascade_on_machine(&machine, &cascade, &opts).unwrap();

    assert!(r.stats.latency_cycles > 0.0);
    assert!(r.stats.energy_pj > 0.0);
    assert_eq!(r.assignment.len(), cascade.ops.len());
    assert_eq!(r.stats.busy_fraction.len(), machine.sub_accels.len());

    // Busy time reconstructed from the fractions must equal the summed
    // interval lengths, which must equal the scheduled per-op latencies.
    let busy_from_fractions: f64 = r
        .stats
        .busy_fraction
        .iter()
        .map(|b| b * r.stats.latency_cycles)
        .sum();
    let interval_sum: f64 = r.sched.intervals.iter().map(|iv| iv.end - iv.start).sum();
    assert!(
        (busy_from_fractions - interval_sum).abs() <= 1e-6 * interval_sum,
        "busy {busy_from_fractions} vs intervals {interval_sum}"
    );
    // Every op runs exactly once, on a unit whose role accepts it.
    assert_eq!(r.sched.intervals.len(), cascade.ops.len());
    // At least two units saw work (the low side has two candidates and
    // the allocator balances across them).
    let active = r.stats.busy_fraction.iter().filter(|&&b| b > 0.0).count();
    assert!(active >= 2, "busy fractions {:?}", r.stats.busy_fraction);
}

/// A custom deep hierarchy (5 storage levels) flows through the mapper
/// and cost model end to end — the level walk is index-based.
#[test]
fn deep_custom_hierarchy_evaluates() {
    let doc = r#"{
      "name": "deep",
      "root": { "level": "DRAM", "bw_words_per_cycle": 256,
        "children": [
          { "level": "LLB", "size_words": 4194304, "bw_words_per_cycle": 256,
            "children": [
              { "level": "L2", "size_words": 1048576, "bw_words_per_cycle": 512,
                "children": [
                  { "level": "L1", "size_words": 131072, "bw_words_per_cycle": 1024,
                    "accels": [ { "name": "deep-array", "role": "unified",
                                  "rows": 64, "cols": 64 } ] } ] } ] } ] } }"#;
    let topo = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap();
    let machine = MachineConfig::from_topology(topo).unwrap();
    assert_eq!(machine.sub_accels[0].spec.levels.len(), 5); // RF,L1,L2,LLB,DRAM

    let wl = transformer::bert_large();
    let cascade = transformer::encoder_cascade(&wl);
    let opts = EvalOptions { samples: 30, ..EvalOptions::default() };
    let r = evaluate_cascade_on_machine(&machine, &cascade, &opts).unwrap();
    assert!(r.stats.latency_cycles > 0.0);
    // The custom L2 level shows up in the energy breakdown and survives
    // the JSON round trip.
    let l2 = harp::arch::level::LevelKind::named("L2");
    assert!(r.stats.energy_by_level.get(&l2).copied().unwrap_or(0.0) > 0.0);
    let back =
        harp::hhp::stats::CascadeStats::from_json(&r.stats.to_json()).expect("round-trips");
    assert_eq!(back.energy_by_level, r.stats.energy_by_level);
}

/// Differential back-compat: every shipped example evaluated with
/// `contention: "off"` is byte-identical to the pre-contention pipeline
/// — i.e. to the machine exactly as `from_topology` builds it, with
/// specs straight from the historical `flatten` (the flatten-vs-direct
/// equality harness extended across the contention boundary).
#[test]
fn examples_with_contention_off_match_pre_contention_output() {
    let wl = transformer::bert_large();
    let cascade = transformer::encoder_cascade(&wl);
    for file in EXAMPLES {
        let topo = load(file);
        // Spec-level: flatten_with(Off) IS the historical flatten.
        for i in 0..topo.accels.len() {
            let old = topo.flatten(i);
            let off = topo.flatten_with(i, ContentionMode::Off);
            assert_eq!(old.levels.len(), off.levels.len(), "{file}");
            for (a, b) in old.levels.iter().zip(&off.levels) {
                assert_eq!(a.kind, b.kind, "{file}");
                assert_eq!(a.size_words, b.size_words, "{file}");
                assert_eq!(a.bw_words_per_cycle, b.bw_words_per_cycle, "{file}");
                assert_eq!(a.energy_pj_per_word, b.energy_pj_per_word, "{file}");
            }
        }
        // End-to-end: a Booked→Off round trip through the machine view
        // leaves the full evaluation document byte-identical.
        let pristine = MachineConfig::from_topology(topo).unwrap();
        let round_tripped = pristine
            .clone()
            .with_contention(ContentionMode::Booked)
            .unwrap()
            .with_contention(ContentionMode::Off)
            .unwrap();
        let opts = EvalOptions { samples: 12, ..EvalOptions::default() };
        let a = evaluate_cascade_on_machine(&pristine, &cascade, &opts).unwrap();
        let b = evaluate_cascade_on_machine(&round_tripped, &cascade, &opts).unwrap();
        assert_eq!(
            a.stats.to_json().to_string_pretty(),
            b.stats.to_json().to_string_pretty(),
            "{file}: contention off drifted from the pre-contention output"
        );
    }
}

/// The shared-LLB example actually books: its pinned shares are honoured
/// verbatim, sum to the node, and the contended evaluation runs end to
/// end with per-node occupancy reported.
#[test]
fn shared_llb_example_books_and_evaluates_contended() {
    let topo = load("hier_xnode_shared_llb.json");
    assert_eq!(topo.accels[1].capacity_share, Some(419430));
    assert_eq!(topo.accels[2].capacity_share, Some(419431));
    let m = MachineConfig::from_topology(topo)
        .unwrap()
        .with_contention(ContentionMode::Booked)
        .unwrap();
    use harp::arch::level::LevelKind;
    let lo1 = m.sub_accels[1].spec.level(LevelKind::LLB).unwrap().size_words;
    let lo2 = m.sub_accels[2].spec.level(LevelKind::LLB).unwrap().size_words;
    assert_eq!((lo1, lo2), (419430, 419431));
    assert_eq!(lo1 + lo2, 838861);
    // The high unit's private LLB is untouched.
    assert_eq!(m.sub_accels[0].spec.level(LevelKind::LLB).unwrap().size_words, 3355443);

    let wl = transformer::llama2();
    let cascade = transformer::cascade_for(&wl);
    let mut opts = EvalOptions { samples: 20, ..EvalOptions::default() };
    opts.contention = ContentionMode::Booked;
    let r = evaluate_cascade_on_machine(&m, &cascade, &opts).unwrap();
    assert!(r.stats.latency_cycles > 0.0);
    // The shared LLB node shows up in the contention report.
    let shared = r
        .stats
        .node_contention
        .iter()
        .find(|c| c.node == "llb.low.shared")
        .expect("shared node reported");
    assert_eq!(shared.users, 2);
    assert!(shared.contended_frac <= shared.occupied_frac);
}

/// Malformed topology documents return `Err` — never panic: truncated
/// JSON at every byte boundary, over-subscribed/degenerate capacity
/// shares, and shares on non-attachment edges.
#[test]
fn malformed_topologies_error_instead_of_panicking() {
    // Truncations of a real document: either the JSON parser or the
    // topology parser must reject every proper prefix.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/topologies/hier_xnode_shared_llb.json");
    let text = std::fs::read_to_string(&path).unwrap();
    // Cut strictly inside the document: a cut in the trailing
    // whitespace would leave a complete, valid file.
    let doc_len = text.trim_end().len();
    for cut in (0..doc_len - 1).step_by(97).chain([doc_len - 1]) {
        let truncated = &text[..cut];
        let outcome = Json::parse(truncated).map_err(|e| e.to_string()).and_then(|j| {
            MachineTopology::from_json(&j).map(|_| ())
        });
        assert!(outcome.is_err(), "truncation at byte {cut} was accepted");
    }

    let shared_llb = |accels: &str| -> Result<MachineTopology, String> {
        let doc = format!(
            r#"{{"name":"m","root":{{"bw_words_per_cycle":256,"children":[
                {{"level":"LLB","size_words":4096,"bw_words_per_cycle":128,
                  "accels":[{accels}]}}]}}}}"#
        );
        MachineTopology::from_json(&Json::parse(&doc).unwrap())
    };
    // Over-subscribed pinned capacity.
    let err = shared_llb(
        r#"{"name":"a","rows":4,"cols":4,"capacity_share_words":4000},
           {"name":"b","rows":4,"cols":4,"capacity_share_words":4000}"#,
    )
    .unwrap_err();
    assert!(err.contains("capacity shares sum"), "{err}");
    // Pins that starve an unpinned sibling.
    let err = shared_llb(
        r#"{"name":"a","rows":4,"cols":4,"capacity_share_words":4096},
           {"name":"b","rows":4,"cols":4}"#,
    )
    .unwrap_err();
    assert!(err.contains("unpinned"), "{err}");
    // Zero and negative shares.
    for bad in ["0", "-16"] {
        let err = shared_llb(&format!(
            r#"{{"name":"a","rows":4,"cols":4,"capacity_share_words":{bad}}},
               {{"name":"b","rows":4,"cols":4}}"#
        ))
        .unwrap_err();
        assert!(err.contains("positive"), "{bad}: {err}");
    }
    // A share on a storage node (non-attachment edge).
    let doc = r#"{"name":"m","root":{"bw_words_per_cycle":256,"children":[
        {"level":"LLB","size_words":4096,"bw_words_per_cycle":128,
         "capacity_share_words":64,
         "accels":[{"name":"a","rows":4,"cols":4}]}]}}"#;
    let err = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap_err();
    assert!(err.contains("not storage nodes"), "{err}");

    // A zero-PE array: previously this could reach the allocator and
    // panic on a NaN load ratio — it must be rejected loudly at load.
    for (rows, cols) in [(0u64, 8u64), (8, 0)] {
        let doc = format!(
            r#"{{"name":"m","root":{{"bw_words_per_cycle":256,"children":[
                {{"level":"LLB","size_words":4096,"bw_words_per_cycle":128,
                  "accels":[{{"name":"a","rows":{rows},"cols":{cols}}}]}}]}}}}"#
        );
        let err = MachineTopology::from_json(&Json::parse(&doc).unwrap()).unwrap_err();
        assert!(err.contains("empty PE array"), "rows={rows} cols={cols}: {err}");
    }
}

/// Pinned per-edge shares change the dynamic re-grant (the recursive
/// tree path), and an all-busy grant never exceeds the root bandwidth.
#[test]
fn pinned_edge_shares_flow_through_scheduler_path() {
    let mut t = load("herald_cross_node.json");
    assert!(!t.custom_edge_shares());
    t.nodes[1].dram_share = Some(32.0);
    assert!(t.custom_edge_shares());
    let machine = MachineConfig::from_topology(t).unwrap();
    let both: f64 = (0..2).map(|s| machine.dynamic_dram_bw(s, &[true, true])).sum();
    assert!(both <= 256.0 * (1.0 + 1e-9), "grants {both} exceed the root");
    // The pinned subtree bids 32 instead of its unit's 64.
    let hi = machine.dynamic_dram_bw(0, &[true, true]);
    assert!((hi - 256.0 * 32.0 / 224.0).abs() < 1e-9);
}
