//! Integration: explicit `--topology` machine trees through the full
//! evaluation pipeline, and the shipped example files.

use harp::arch::partition::MachineConfig;
use harp::arch::taxonomy::{ComputePlacement, HeterogeneityLoc};
use harp::arch::topology::MachineTopology;
use harp::coordinator::experiment::{evaluate_cascade_on_machine, EvalOptions};
use harp::util::json::Json;
use harp::workload::transformer;
use std::path::PathBuf;

fn load(name: &str) -> MachineTopology {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("topologies")
        .join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    MachineTopology::from_json(&Json::parse(&text).expect("valid JSON"))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Every shipped example classifies to the taxonomy row it illustrates.
#[test]
fn example_topologies_classify_to_their_rows() {
    let cases: [(&str, ComputePlacement, HeterogeneityLoc); 5] = [
        ("b100_intra_node.json", ComputePlacement::LeafOnly, HeterogeneityLoc::IntraNode),
        (
            "herald_cross_node.json",
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::CrossNode { clustered: false },
        ),
        (
            "symphony_clustered.json",
            ComputePlacement::LeafOnly,
            HeterogeneityLoc::CrossNode { clustered: true },
        ),
        (
            "neupim_cross_depth.json",
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::CrossDepth,
        ),
        (
            "fig4h_compound.json",
            ComputePlacement::Hierarchical,
            HeterogeneityLoc::Compound(vec![
                HeterogeneityLoc::CrossNode { clustered: false },
                HeterogeneityLoc::CrossDepth,
            ]),
        ),
    ];
    for (file, placement, het) in cases {
        let t = load(file);
        let c = t.classify().unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(c.placement, placement, "{file}");
        assert_eq!(c.heterogeneity, het, "{file}");
    }
}

/// Acceptance: a ≥3-sub-accelerator topology evaluates end-to-end, and
/// the scheduler's busy fractions are consistent with the makespan —
/// `busy_fraction[s] · makespan` sums to the total scheduled op time.
#[test]
fn three_accel_topology_evaluates_end_to_end() {
    let machine = MachineConfig::from_topology(load("fig4h_compound.json")).unwrap();
    assert!(machine.sub_accels.len() >= 3, "need ≥3 sub-accelerators");

    let wl = transformer::llama2();
    let cascade = transformer::cascade_for(&wl);
    let opts = EvalOptions { samples: 40, ..EvalOptions::default() };
    let r = evaluate_cascade_on_machine(&machine, &cascade, &opts).unwrap();

    assert!(r.stats.latency_cycles > 0.0);
    assert!(r.stats.energy_pj > 0.0);
    assert_eq!(r.assignment.len(), cascade.ops.len());
    assert_eq!(r.stats.busy_fraction.len(), machine.sub_accels.len());

    // Busy time reconstructed from the fractions must equal the summed
    // interval lengths, which must equal the scheduled per-op latencies.
    let busy_from_fractions: f64 = r
        .stats
        .busy_fraction
        .iter()
        .map(|b| b * r.stats.latency_cycles)
        .sum();
    let interval_sum: f64 = r.sched.intervals.iter().map(|iv| iv.end - iv.start).sum();
    assert!(
        (busy_from_fractions - interval_sum).abs() <= 1e-6 * interval_sum,
        "busy {busy_from_fractions} vs intervals {interval_sum}"
    );
    // Every op runs exactly once, on a unit whose role accepts it.
    assert_eq!(r.sched.intervals.len(), cascade.ops.len());
    // At least two units saw work (the low side has two candidates and
    // the allocator balances across them).
    let active = r.stats.busy_fraction.iter().filter(|&&b| b > 0.0).count();
    assert!(active >= 2, "busy fractions {:?}", r.stats.busy_fraction);
}

/// A custom deep hierarchy (5 storage levels) flows through the mapper
/// and cost model end to end — the level walk is index-based.
#[test]
fn deep_custom_hierarchy_evaluates() {
    let doc = r#"{
      "name": "deep",
      "root": { "level": "DRAM", "bw_words_per_cycle": 256,
        "children": [
          { "level": "LLB", "size_words": 4194304, "bw_words_per_cycle": 256,
            "children": [
              { "level": "L2", "size_words": 1048576, "bw_words_per_cycle": 512,
                "children": [
                  { "level": "L1", "size_words": 131072, "bw_words_per_cycle": 1024,
                    "accels": [ { "name": "deep-array", "role": "unified",
                                  "rows": 64, "cols": 64 } ] } ] } ] } ] } }"#;
    let topo = MachineTopology::from_json(&Json::parse(doc).unwrap()).unwrap();
    let machine = MachineConfig::from_topology(topo).unwrap();
    assert_eq!(machine.sub_accels[0].spec.levels.len(), 5); // RF,L1,L2,LLB,DRAM

    let wl = transformer::bert_large();
    let cascade = transformer::encoder_cascade(&wl);
    let opts = EvalOptions { samples: 30, ..EvalOptions::default() };
    let r = evaluate_cascade_on_machine(&machine, &cascade, &opts).unwrap();
    assert!(r.stats.latency_cycles > 0.0);
    // The custom L2 level shows up in the energy breakdown and survives
    // the JSON round trip.
    let l2 = harp::arch::level::LevelKind::named("L2");
    assert!(r.stats.energy_by_level.get(&l2).copied().unwrap_or(0.0) > 0.0);
    let back =
        harp::hhp::stats::CascadeStats::from_json(&r.stats.to_json()).expect("round-trips");
    assert_eq!(back.energy_by_level, r.stats.energy_by_level);
}

/// Pinned per-edge shares change the dynamic re-grant (the recursive
/// tree path), and an all-busy grant never exceeds the root bandwidth.
#[test]
fn pinned_edge_shares_flow_through_scheduler_path() {
    let mut t = load("herald_cross_node.json");
    assert!(!t.custom_edge_shares());
    t.nodes[1].dram_share = Some(32.0);
    assert!(t.custom_edge_shares());
    let machine = MachineConfig::from_topology(t).unwrap();
    let both: f64 = (0..2).map(|s| machine.dynamic_dram_bw(s, &[true, true])).sum();
    assert!(both <= 256.0 * (1.0 + 1e-9), "grants {both} exceed the root");
    // The pinned subtree bids 32 instead of its unit's 64.
    let hi = machine.dynamic_dram_bw(0, &[true, true]);
    assert!((hi - 256.0 * 32.0 / 224.0).abs() < 1e-9);
}
