//! End-to-end serving-engine tests over REAL calibrated costs: the
//! arrival generator, the calibration probes (through the shared
//! evaluator), and the continuous-batching simulator together, at a
//! small fixed mapper budget.
//!
//! The heart is the determinism contract from the issue: a fixed
//! (stream seed, machine, bandwidth) triple must produce byte-identical
//! serving reports whether calibration ran on one worker or many, and
//! across repeat runs — with the default knobs AND with every
//! non-default knob (class mixes, paged booking, pressure placement)
//! engaged at once.

use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::EvalOptions;
use harp::coordinator::figures::Evaluator;
use harp::runtime::serve::{
    self, build_serving_machine, calibrate, simulate, PlacementPolicy, ServeConfig,
};
use harp::workload::arrivals::{
    synthesize, ArrivalKind, Request, RequestClass, RequestFamily, StreamParams,
};

fn small_opts(threads: usize) -> EvalOptions {
    let mut o = EvalOptions { samples: 8, ..EvalOptions::default() };
    o.seed = 0x5E47_11CE;
    o.threads = threads;
    o
}

fn stream(kind: ArrivalKind, load: f64, n: usize, seed: u64) -> Vec<Request> {
    stream_classed(kind, load, n, seed, vec![])
}

fn stream_classed(
    kind: ArrivalKind,
    load: f64,
    n: usize,
    seed: u64,
    classes: Vec<(RequestClass, f64)>,
) -> Vec<Request> {
    synthesize(&StreamParams {
        kind,
        mix: RequestFamily::ALL.iter().map(|&f| (f, 1.0)).collect(),
        classes,
        load,
        requests: n,
        seed,
    })
    .unwrap()
}

/// One full serve run at a worker count; returns the rendered report.
fn serve_report(threads: usize, kind: ArrivalKind, seed: u64) -> String {
    serve_report_cfg(threads, kind, seed, vec![], &ServeConfig::default())
}

/// Same, with a class mix and non-default engine knobs.
fn serve_report_cfg(
    threads: usize,
    kind: ArrivalKind,
    seed: u64,
    classes: Vec<(RequestClass, f64)>,
    cfg: &ServeConfig,
) -> String {
    let opts = small_opts(threads);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    let reqs = stream_classed(kind, 2.0, 12, seed, classes);
    simulate(&reqs, &machine, &costs, dynamic_bw, 2.0, cfg).unwrap().report.render()
}

/// The acceptance gate: byte-identical reports across HARP_THREADS-style
/// worker counts AND across repeat runs, for both synthetic processes.
#[test]
fn serve_report_byte_identical_across_thread_counts_and_runs() {
    for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty] {
        let serial = serve_report(1, kind, 7);
        let par = serve_report(4, kind, 7);
        let again = serve_report(4, kind, 7);
        assert_eq!(serial, par, "{kind:?}: worker count changed the serving report");
        assert_eq!(par, again, "{kind:?}: repeat run changed the serving report");
    }
}

/// The same gate with every non-default knob engaged at once: a mixed
/// class stream, a separate batch SLO, paged KV booking, and pressure
/// placement. The report (including the per-class breakdown and page
/// counters) must be byte-identical across worker counts and repeats.
#[test]
fn classed_paged_report_byte_identical_across_thread_counts_and_runs() {
    let classes = vec![(RequestClass::Interactive, 1.0), (RequestClass::Batch, 3.0)];
    let cfg = ServeConfig {
        slo_ttft_batch: Some(5.0e6),
        kv_page_words: 4096,
        placement: PlacementPolicy::Pressure,
        ..ServeConfig::default()
    };
    for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty] {
        let serial = serve_report_cfg(1, kind, 7, classes.clone(), &cfg);
        let par = serve_report_cfg(4, kind, 7, classes.clone(), &cfg);
        let again = serve_report_cfg(4, kind, 7, classes.clone(), &cfg);
        assert_eq!(serial, par, "{kind:?}: worker count changed the classed report");
        assert_eq!(par, again, "{kind:?}: repeat run changed the classed report");
        assert!(serial.contains("class interactive"), "missing breakdown:\n{serial}");
        assert!(serial.contains("class batch"), "missing breakdown:\n{serial}");
        assert!(serial.contains("kv pages 4096 words each"), "missing page line:\n{serial}");
    }
}

/// A classless run and a single-class "interactive" run are the SAME
/// stream (class labels ride a separate RNG), and with default engine
/// knobs the single-class report must stay byte-identical to the
/// legacy one — the byte-stable-defaults contract end to end.
#[test]
fn uniform_interactive_mix_matches_legacy_report() {
    let legacy = serve_report(1, ArrivalKind::Poisson, 7);
    let uniform = serve_report_cfg(
        1,
        ArrivalKind::Poisson,
        7,
        vec![(RequestClass::Interactive, 1.0)],
        &ServeConfig::default(),
    );
    assert_eq!(legacy, uniform, "uniform interactive mix moved the default report");
    assert!(!legacy.contains("class "), "default report grew a class breakdown");
    assert!(!legacy.contains("kv pages"), "default report grew a page line");
}

/// Different stream seeds must actually move the report — otherwise the
/// identity test above is vacuous.
#[test]
fn serve_report_depends_on_stream_seed() {
    assert_ne!(serve_report(1, ArrivalKind::Poisson, 7), serve_report(1, ArrivalKind::Poisson, 8));
}

/// Engine invariants under real calibrated costs (not the synthetic
/// unit-test cost table): conservation, causal timestamps, and sane
/// aggregate metrics.
#[test]
fn serve_invariants_under_real_costs() {
    let opts = small_opts(1);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    let reqs = stream(ArrivalKind::Poisson, 4.0, 12, 7);
    let r = simulate(&reqs, &machine, &costs, dynamic_bw, 4.0, &ServeConfig::default()).unwrap();
    assert_eq!(r.report.completed + r.report.rejected, reqs.len());
    assert!(r.report.completed > 0, "nothing completed under real costs");
    for rec in &r.records {
        assert!(rec.admitted >= rec.arrival, "request {} admitted before arriving", rec.id);
        assert!(rec.first_token > rec.admitted, "request {} produced before admission", rec.id);
        assert!(rec.completed >= rec.first_token);
        assert!(rec.ttft() > 0.0);
    }
    assert!(r.report.goodput <= r.report.throughput + 1e-12);
    assert!(r.report.p50_ttft <= r.report.p99_ttft);
    assert!(r.report.kv_capacity_words > 0.0);
}

/// Calibration through the shared evaluator makes the per-family cost
/// table: prefill and decode per-token costs must be positive and
/// finite for every family, and the decode chunk cost must grow with
/// the KV length (the attention-scan term).
#[test]
fn calibrated_costs_are_positive_and_kv_sensitive() {
    let ev = Evaluator::new(small_opts(1));
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    for f in RequestFamily::ALL {
        let fc = costs.family(f);
        assert!(
            fc.prefill_per_token.is_finite() && fc.prefill_per_token > 0.0,
            "{}: bad prefill cost {}",
            f.name(),
            fc.prefill_per_token
        );
        assert!(
            fc.decode_per_token.is_finite() && fc.decode_per_token > 0.0,
            "{}: bad decode cost {}",
            f.name(),
            fc.decode_per_token
        );
    }
}

/// The knee helper applied to a real (tiny) load sweep: goodput curves
/// from the engine always yield a knee that is one of the swept loads.
#[test]
fn knee_lands_on_the_swept_grid() {
    let opts = small_opts(1);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    let loads = [1.0, 4.0];
    let curve: Vec<(f64, f64)> = loads
        .iter()
        .map(|&load| {
            let reqs = stream(ArrivalKind::Poisson, load, 10, 7);
            let r =
                simulate(&reqs, &machine, &costs, dynamic_bw, load, &ServeConfig::default())
                    .unwrap();
            (load, r.report.goodput)
        })
        .collect();
    let knee = serve::saturation_knee(&curve);
    assert!(loads.contains(&knee), "knee {knee} not on the swept grid");
}

fn disagg_cfg() -> ServeConfig {
    ServeConfig {
        disagg: Some(serve::DisaggConfig::parse("prefill=high,decode=low").unwrap()),
        ..ServeConfig::default()
    }
}

/// The disagg determinism gate: role-disaggregated reports (including
/// the hand-off counters) are byte-identical across worker counts and
/// repeat runs — and so are pressure-fed-search reports, alone and
/// stacked with disaggregation.
#[test]
fn disagg_and_pressure_search_reports_byte_identical() {
    let search = ServeConfig { placement: PlacementPolicy::PressureSearch, ..disagg_cfg() };
    let plain_search =
        ServeConfig { placement: PlacementPolicy::PressureSearch, ..ServeConfig::default() };
    for cfg in [disagg_cfg(), plain_search, search] {
        let serial = serve_report_cfg(1, ArrivalKind::Poisson, 7, vec![], &cfg);
        let par = serve_report_cfg(4, ArrivalKind::Poisson, 7, vec![], &cfg);
        let again = serve_report_cfg(4, ArrivalKind::Poisson, 7, vec![], &cfg);
        assert_eq!(serial, par, "worker count changed the report for {cfg:?}");
        assert_eq!(par, again, "repeat run changed the report for {cfg:?}");
    }
    let disagg = serve_report_cfg(1, ArrivalKind::Poisson, 7, vec![], &disagg_cfg());
    assert!(disagg.contains("disagg prefill=high,decode=low"), "missing line:\n{disagg}");
    assert!(disagg.contains("hand-offs"), "missing hand-off counter:\n{disagg}");
}

/// Differential contract end to end under REAL calibrated costs: when
/// every unit accepts both roles the disagg pools coincide, no hand-off
/// is ever charged, and records/report are bitwise the co-located
/// engine's (the render differs only by the gated disagg line).
#[test]
fn disagg_same_pools_is_byte_identical_to_colocated() {
    let opts = small_opts(1);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let mut machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    for sa in &mut machine.sub_accels {
        sa.role = harp::arch::partition::Role::Unified;
    }
    let reqs = stream(ArrivalKind::Poisson, 2.0, 12, 7);
    let colo =
        simulate(&reqs, &machine, &costs, dynamic_bw, 2.0, &ServeConfig::default()).unwrap();
    let dis = simulate(&reqs, &machine, &costs, dynamic_bw, 2.0, &disagg_cfg()).unwrap();
    assert_eq!(dis.report.kv_transfers, 0, "same-pool disagg charged a hand-off");
    assert_eq!(dis.report.kv_transfer_words, 0);
    assert_eq!(colo.records.len(), dis.records.len());
    for (x, y) in colo.records.iter().zip(&dis.records) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.admitted.to_bits(), y.admitted.to_bits());
        assert_eq!(x.first_token.to_bits(), y.first_token.to_bits());
        assert_eq!(x.completed.to_bits(), y.completed.to_bits());
    }
    assert_eq!(colo.report.goodput.to_bits(), dis.report.goodput.to_bits());
    assert_eq!(colo.report.p99_ttft.to_bits(), dis.report.p99_ttft.to_bits());
    let strip = |s: &str| {
        s.lines()
            .filter(|l| !l.trim_start().starts_with("disagg "))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(strip(&colo.report.render()), strip(&dis.report.render()));
}

/// Disaggregation on a heterogeneous point actually moves KV between
/// the pools, under real costs: hand-offs are charged, the words add
/// up, and the run still completes everything it admits.
#[test]
fn disagg_hand_offs_are_charged_under_real_costs() {
    let opts = small_opts(1);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    let reqs = stream(ArrivalKind::Poisson, 2.0, 12, 7);
    let r = simulate(&reqs, &machine, &costs, dynamic_bw, 2.0, &disagg_cfg()).unwrap();
    assert_eq!(r.report.completed + r.report.rejected, reqs.len());
    assert!(r.report.kv_transfers > 0, "no hand-off on a heterogeneous point");
    assert!(r.report.kv_transfer_words > 0);
    // At most one hand-off per admission of a request.
    assert!(r.report.kv_transfers <= r.report.completed + r.report.evictions);
    assert_eq!(r.report.disagg.as_deref(), Some("prefill=high,decode=low"));
}

/// Satellite bugfix pin: a trace whose `arrival` fields are NOT
/// monotone is stable-sorted by the loader (ids renumbered to arrival
/// order, file order breaking ties), and the engine admits in exactly
/// that order — `admitted` is non-decreasing over ids, so the (class,
/// arrival) wait-queue contract holds for out-of-order trace files.
#[test]
fn non_monotone_trace_admits_in_arrival_order() {
    let trace = r#"{ "requests": [
        { "arrival": 5000.0, "family": "llama2", "context": 64, "output": 8 },
        { "arrival": 0.0,    "family": "gqa",    "context": 64, "output": 8 },
        { "arrival": 2500.0, "family": "moe",    "context": 64, "output": 8 },
        { "arrival": 2500.0, "family": "llama2", "context": 64, "output": 8 }
    ] }"#;
    let reqs = harp::workload::arrivals::load_trace(trace).unwrap();
    // Loader contract: arrival-sorted, ids renumbered, ties in file
    // order (moe before the same-arrival llama2).
    let arrivals: Vec<f64> = reqs.iter().map(|r| r.arrival).collect();
    assert_eq!(arrivals, vec![0.0, 2500.0, 2500.0, 5000.0]);
    assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    assert_eq!(reqs[1].family, RequestFamily::Moe, "tie broke file order");

    let opts = small_opts(1);
    let (dynamic_bw, contention) = (opts.dynamic_bw, opts.contention);
    let ev = Evaluator::new(opts);
    let class = HarpClass::from_id("hier+xnode").unwrap();
    let costs = calibrate(&ev, &class, 2048.0, &RequestFamily::ALL);
    let machine = build_serving_machine(&class, 2048.0, contention).unwrap();
    let r = simulate(&reqs, &machine, &costs, dynamic_bw, 2.0, &ServeConfig::default()).unwrap();
    assert_eq!(r.report.completed, 4);
    // Engine contract: first admissions follow id (= arrival) order.
    let mut by_id: Vec<&harp::runtime::serve::RequestRecord> = r.records.iter().collect();
    by_id.sort_by_key(|rec| rec.id);
    for w in by_id.windows(2) {
        assert!(
            w[0].admitted <= w[1].admitted,
            "request {} admitted after request {} despite arriving first",
            w[0].id,
            w[1].id
        );
    }
    for rec in &by_id {
        assert!(rec.admitted >= rec.arrival);
    }
}
