//! Integration: the workload JSON front-end — the shipped example
//! files, loader robustness (truncation sweep, malformed documents with
//! a distinct error each), and file-cascade evaluation end to end.
//! Mirrors `integration_topology.rs` for the machine front-end.

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::util::json::Json;
use harp::workload::registry;
use harp::workload::Cascade;
use std::path::PathBuf;

const EXAMPLES: [&str; 5] = [
    "moe_decode.json",
    "moe_prefill.json",
    "conv_resnet.json",
    "gqa_decode.json",
    "serving_mix.json",
];

fn example_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("workloads")
        .join(name)
}

fn load(name: &str) -> Cascade {
    let path = example_path(name);
    let text =
        std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    Cascade::from_json(&Json::parse(&text).expect("valid JSON"))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Every shipped example parses, validates, reaches a serialization
/// fixpoint, and evaluates end to end on a heterogeneous machine.
#[test]
fn example_workloads_parse_and_evaluate() {
    for file in EXAMPLES {
        let g = load(file);
        g.validate().unwrap_or_else(|e| panic!("{file}: {e}"));
        let text = g.to_json().to_string_pretty();
        let back = Cascade::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text, "{file}");

        let class = HarpClass::from_id("leaf+xnode").unwrap();
        let opts = EvalOptions { samples: 8, ..EvalOptions::default() };
        let r = evaluate_cascade_on_config(&class, &HardwareParams::default(), &g, &opts)
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        assert!(r.stats.latency_cycles > 0.0, "{file}");
        assert!(r.stats.energy_pj > 0.0, "{file}");
        assert_eq!(r.assignment.len(), g.ops.len(), "{file}");
    }
}

/// The registry resolves example files as path-shaped values, and the
/// resulting spec round-trips through the evaluation-cache key.
#[test]
fn registry_resolves_example_files() {
    let path = example_path("moe_decode.json");
    let wl = registry::resolve(path.to_str().unwrap()).unwrap();
    assert_eq!(wl.name(), "moe-decode-example");
    assert_eq!(wl.family(), "file");
    assert!(wl.cache_key().starts_with("file:moe-decode-example:"), "{}", wl.cache_key());
    // A registered name resolves to the built-in, never a file.
    assert_eq!(registry::resolve("moe_decode").unwrap().family(), "moe");
}

/// Malformed workload documents return `Err` — never panic: truncated
/// JSON at every byte boundary of a real example file.
#[test]
fn truncated_workload_documents_error() {
    let path = example_path("moe_decode.json");
    let text = std::fs::read_to_string(&path).unwrap();
    // Cut strictly inside the document: a cut in the trailing
    // whitespace would leave a complete, valid file.
    let doc_len = text.trim_end().len();
    for cut in (0..doc_len - 1).step_by(97).chain([doc_len - 1]) {
        let truncated = &text[..cut];
        let outcome = Json::parse(truncated)
            .map_err(|e| e.to_string())
            .and_then(|j| Cascade::from_json(&j).map(|_| ()));
        assert!(outcome.is_err(), "truncation at byte {cut} was accepted");
    }
}
