//! Integration: the paper's headline TRENDS hold end-to-end through the
//! full pipeline (partition → allocate → map → schedule → aggregate) at
//! a reduced mapper budget.
//!
//! These are the §VII "Summary of Key Trends" bullets as assertions.

use harp::arch::level::LevelKind;
use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions, EvalResult};
use harp::workload::transformer;

fn eval(wl_name: &str, machine: &str, bw_bits: f64, frac_low: Option<f64>) -> EvalResult {
    let wl = transformer::by_name(wl_name).unwrap();
    let cascade = transformer::cascade_for(&wl);
    let mut opts = EvalOptions { samples: 150, ..EvalOptions::default() };
    opts.bw_frac_low = frac_low;
    let params = HardwareParams { dram_bw_bits: bw_bits, ..HardwareParams::default() };
    evaluate_cascade_on_config(&HarpClass::from_id(machine).unwrap(), &params, &cascade, &opts)
        .unwrap()
}

/// Trend 1a: encoder-only (BERT) favours the homogeneous machine.
#[test]
fn bert_homogeneous_wins_latency() {
    let homo = eval("bert", "leaf+homo", 2048.0, None);
    for het in ["leaf+xnode", "leaf+intra", "hier+xdepth"] {
        let r = eval("bert", het, 2048.0, None);
        assert!(
            r.stats.latency_cycles >= homo.stats.latency_cycles,
            "{het}: {:.3e} should not beat homo {:.3e}",
            r.stats.latency_cycles,
            homo.stats.latency_cycles
        );
    }
}

/// Trend 1b: decoder workloads favour heterogeneous machines (overlap
/// of prefill and decode).
#[test]
fn decoders_heterogeneous_wins_latency() {
    for wl in ["llama2", "gpt3"] {
        let homo = eval(wl, "leaf+homo", 2048.0, None);
        let het = eval(wl, "leaf+xnode", 2048.0, None);
        assert!(
            het.stats.latency_cycles < homo.stats.latency_cycles * 1.001,
            "{wl}: xnode {:.3e} vs homo {:.3e}",
            het.stats.latency_cycles,
            homo.stats.latency_cycles
        );
    }
}

/// Trend 2: heterogeneous machines need less energy than homogeneous
/// (paper: ~10% encoder / ~20% decoder), and the homogeneous machine is
/// the least energy-efficient.
#[test]
fn heterogeneous_saves_energy() {
    for wl in ["bert", "llama2", "gpt3"] {
        let homo = eval(wl, "leaf+homo", 2048.0, None);
        let xnode = eval(wl, "leaf+xnode", 2048.0, None);
        let xdepth = eval(wl, "hier+xdepth", 2048.0, None);
        assert!(xnode.stats.energy_pj < homo.stats.energy_pj, "{wl}: xnode energy");
        assert!(xdepth.stats.energy_pj < homo.stats.energy_pj, "{wl}: xdepth energy");
        assert!(
            xnode.stats.mults_per_joule() > homo.stats.mults_per_joule(),
            "{wl}: homo must be least energy-efficient"
        );
    }
}

/// Trend 3: energy is DRAM-dominated for decoder models and
/// RF-dominated for the encoder model.
#[test]
fn energy_breakdown_by_workload_type() {
    let bert = eval("bert", "leaf+homo", 2048.0, None);
    let rf = bert.stats.energy_by_level[&LevelKind::RF];
    let dram = bert.stats.energy_by_level[&LevelKind::DRAM];
    assert!(rf > dram, "BERT: RF {rf:.3e} should dominate DRAM {dram:.3e}");

    let gpt = eval("gpt3", "leaf+homo", 2048.0, None);
    let rf = gpt.stats.energy_by_level[&LevelKind::RF];
    let dram = gpt.stats.energy_by_level[&LevelKind::DRAM];
    assert!(dram > rf, "GPT3: DRAM {dram:.3e} should dominate RF {rf:.3e}");
}

/// Trend 4: 50/50 bandwidth partitioning erodes the decoder advantage
/// relative to the 75/25 policy (Fig 10).
#[test]
fn naive_bandwidth_split_erodes_decoder_advantage() {
    for wl in ["llama2", "gpt3"] {
        let good = eval(wl, "leaf+xnode", 2048.0, Some(0.75));
        let naive = eval(wl, "leaf+xnode", 2048.0, Some(0.5));
        assert!(
            naive.stats.latency_cycles > good.stats.latency_cycles,
            "{wl}: 50/50 ({:.3e}) must be slower than 75/25 ({:.3e})",
            naive.stats.latency_cycles,
            good.stats.latency_cycles
        );
    }
}

/// Trend 5: on-chip (memory-system) energy is dominated by high-reuse
/// operations for BERT, and by low-reuse operations for decoder models
/// at the single-request operating point (Fig 9). At the serving batch
/// used for the performance figures, prefill compute grows with batch
/// and the balance tips to the high-reuse side — see EXPERIMENTS.md.
#[test]
fn onchip_energy_role_split() {
    let bert = eval("bert", "leaf+xnode", 2048.0, None);
    assert!(
        bert.stats.buffer_energy_by_role["high-reuse"]
            > bert.stats.buffer_energy_by_role["low-reuse"],
        "BERT on-chip energy should be high-reuse dominated"
    );
    // Single-request decoding: decode is pure weight/KV streaming.
    let mut wl = transformer::llama2();
    wl.batch = 1;
    let cascade = transformer::cascade_for(&wl);
    let opts = EvalOptions { samples: 150, ..EvalOptions::default() };
    let llama = evaluate_cascade_on_config(
        &HarpClass::from_id("leaf+xnode").unwrap(),
        &HardwareParams::default(),
        &cascade,
        &opts,
    )
    .unwrap();
    assert!(
        llama.stats.buffer_energy_by_role["low-reuse"]
            > llama.stats.buffer_energy_by_role["high-reuse"],
        "Llama (batch 1) on-chip energy should be low-reuse dominated: {:?}",
        llama.stats.buffer_energy_by_role
    );
}

/// Trend 6: the cross-depth point has the lowest energy of the
/// heterogeneous configs for decoder workloads (skips a hierarchy
/// level for the dominant low-reuse traffic).
#[test]
fn cross_depth_lowest_energy_decoder() {
    let gpt_xd = eval("gpt3", "hier+xdepth", 2048.0, None);
    for other in ["leaf+homo", "leaf+xnode", "leaf+intra"] {
        let r = eval("gpt3", other, 2048.0, None);
        assert!(
            gpt_xd.stats.energy_pj <= r.stats.energy_pj,
            "xdepth {:.3e} should have least energy vs {other} {:.3e}",
            gpt_xd.stats.energy_pj,
            r.stats.energy_pj
        );
    }
}

/// The BERT utilisation zoom (Fig 6): the homogeneous machine sustains
/// higher PE-weighted utilisation than the cross-node machine.
#[test]
fn bert_utilization_zoom() {
    let homo = eval("bert", "leaf+homo", 2048.0, None);
    let het = eval("bert", "leaf+xnode", 2048.0, None);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&homo.stats.utilization_timeline) > mean(&het.stats.utilization_timeline),
        "homo should keep more of the machine busy on BERT"
    );
}

/// Decoder phases land on the right units and actually overlap.
#[test]
fn decoder_phases_overlap_on_heterogeneous() {
    let r = eval("gpt3", "leaf+xnode", 2048.0, None);
    // Both units substantially busy (overlap happened).
    assert!(r.stats.busy_fraction[0] > 0.3, "high unit busy {:?}", r.stats.busy_fraction);
    assert!(r.stats.busy_fraction[1] > 0.3, "low unit busy {:?}", r.stats.busy_fraction);
    // Makespan strictly below the serial sum of all op latencies: the
    // machine genuinely ran prefill and decode concurrently.
    let wl = transformer::by_name("gpt3").unwrap();
    let cascade = transformer::cascade_for(&wl);
    let serial: f64 = r
        .mapped
        .iter()
        .map(|m| m.stats.cycles * cascade.ops[m.op_index].count as f64)
        .sum();
    assert!(
        r.stats.latency_cycles < serial * 0.999,
        "makespan {:.3e} should be under serial sum {serial:.3e}",
        r.stats.latency_cycles
    );
}

/// Bandwidth sweep: halving DRAM bandwidth must not speed anything up,
/// and must slow bandwidth-bound decoders nearly proportionally.
#[test]
fn bandwidth_sweep_monotone() {
    for wl in ["bert", "gpt3"] {
        for machine in ["leaf+homo", "leaf+xnode"] {
            let fast = eval(wl, machine, 2048.0, None);
            let slow = eval(wl, machine, 512.0, None);
            assert!(
                slow.stats.latency_cycles >= fast.stats.latency_cycles,
                "{wl}/{machine}: lower bw cannot be faster"
            );
        }
    }
}
