//! Integration: the `harp` binary's CLI surface.

use std::process::Command;

fn harp(args: &[&str]) -> (bool, String, String) {
    harp_env(args, &[])
}

fn harp_env(args: &[&str], envs: &[(&str, &str)]) -> (bool, String, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_harp"));
    cmd.args(args);
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn taxonomy_prints_table1() {
    let (ok, stdout, _) = harp(&["taxonomy"]);
    assert!(ok);
    for name in ["TPUv1", "NeuPIM", "Symphony", "Herald"] {
        assert!(stdout.contains(name), "missing {name}");
    }
}

#[test]
fn classify_known_work() {
    let (ok, stdout, _) = harp(&["classify", "duplex"]);
    assert!(ok);
    assert!(stdout.contains("cross-depth"));
}

#[test]
fn classify_unknown_fails() {
    let (ok, _, stderr) = harp(&["classify", "not-an-accelerator"]);
    assert!(!ok);
    assert!(stderr.contains("no prior work"));
}

#[test]
fn eval_emits_json() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--machine",
        "leaf+xnode",
        "--samples",
        "60",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(v.get("machine").unwrap().as_str(), Some("leaf+xnode"));
}

#[test]
fn eval_contention_flag_flows_to_report() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "llama2",
        "--machine",
        "hier+xnode",
        "--samples",
        "20",
        "--contention",
        "on",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    // hier+xnode shares its low LLB between two units: the occupancy
    // report must list that node (plus the root) with 2 and 3 users.
    let nodes = v.get("node_contention").unwrap().as_arr().unwrap();
    assert!(
        nodes
            .iter()
            .any(|c| c.get("node").unwrap().as_str() == Some("llb.low")
                && c.get("users").unwrap().as_usize() == Some(2)),
        "{stdout}"
    );
    // An unknown mode is a usage error, not a silent default.
    let (ok, _, stderr) = harp(&[
        "eval", "--workload", "bert", "--machine", "leaf+xnode", "--contention", "sometimes",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown contention mode"), "{stderr}");
}

#[test]
fn eval_alloc_search_smokes_and_reports_assignment() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "llama2",
        "--machine",
        "hier+xnode",
        "--samples",
        "10",
        "--alloc",
        "search",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert_eq!(v.get("alloc").unwrap().as_str(), Some("search"));
    let assignment = v.get("assignment").unwrap().as_arr().unwrap();
    assert!(!assignment.is_empty());
    // The default (greedy) stays byte-compatible: no alloc keys at all.
    let (ok, stdout, stderr) = harp(&[
        "eval", "--workload", "llama2", "--machine", "hier+xnode", "--samples", "10",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("alloc").is_none());
    assert!(v.get("assignment").is_none());
}

#[test]
fn eval_unknown_alloc_policy_lists_valid_set() {
    let (ok, _, stderr) = harp(&[
        "eval", "--workload", "bert", "--machine", "leaf+xnode", "--alloc", "optimal",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown allocation policy"), "{stderr}");
    for name in ["greedy", "round_robin", "critical_path", "search"] {
        assert!(stderr.contains(name), "valid set missing '{name}': {stderr}");
    }
}

#[test]
fn eval_config_rejects_cli_alloc_flag() {
    let dir = std::env::temp_dir().join("harp_cli_config_alloc_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("cfg.json");
    std::fs::write(
        &cfg,
        r#"{"workload":"bert","machine":"leaf+homo","samples":10,"alloc":"round_robin"}"#,
    )
    .unwrap();
    let cfg = cfg.to_string_lossy().into_owned();
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg, "--alloc", "search"]);
    assert!(!ok, "--alloc alongside --config must fail");
    assert!(stderr.contains("--config supplies the evaluation options"), "{stderr}");
    assert!(stderr.contains("\"alloc\""), "{stderr}");
    // The config's own alloc key still drives the evaluation.
    let (ok, stdout, stderr) = harp(&["eval", "--config", &cfg, "--json"]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert_eq!(v.get("alloc").unwrap().as_str(), Some("round_robin"));
}

#[test]
fn eval_mapping_cache_round_trips_and_rejections_are_loud() {
    let dir = std::env::temp_dir().join("harp_cli_mapping_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cache = dir.join("mappings.json");
    std::fs::remove_file(&cache).ok();
    let cache_s = cache.to_string_lossy().into_owned();
    let eval = |extra: &[&str]| {
        let mut args = vec![
            "eval", "--workload", "llama2", "--machine", "hier+xnode", "--samples", "10",
            "--alloc", "search", "--json",
        ];
        args.extend_from_slice(extra);
        harp(&args)
    };
    let (ok, plain, stderr) = eval(&[]);
    assert!(ok, "stderr: {stderr}");
    let (ok, cold, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(plain, cold, "a cold mapping cache changed the --json output");
    assert!(cache.exists(), "eval must spill the mapping cache before exiting");
    let (ok, warm, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(plain, warm, "a warm mapping cache changed the --json output");

    // A cache searched under a different budget must be rejected, not
    // silently served (its mappings would change results).
    let (ok, _, stderr) = harp(&[
        "eval", "--workload", "llama2", "--machine", "hier+xnode", "--samples", "12",
        "--alloc", "search", "--mapping-cache", &cache_s,
    ]);
    assert!(!ok, "a stale-budget cache must fail the run");
    assert!(stderr.contains("stale mapping cache"), "{stderr}");

    // So must a corrupt file.
    std::fs::write(&cache, "{ not json").unwrap();
    let (ok, _, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(!ok, "a corrupt cache must fail the run");
    assert!(stderr.contains("malformed mapping cache"), "{stderr}");

    // --config supplies the evaluation options; the flag alongside it
    // is a conflict, not a shadowing.
    let cfg = dir.join("cfg.json");
    std::fs::write(&cfg, r#"{"workload":"bert","machine":"leaf+homo","samples":10}"#)
        .unwrap();
    let cfg_s = cfg.to_string_lossy().into_owned();
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg_s, "--mapping-cache", &cache_s]);
    assert!(!ok, "--mapping-cache alongside --config must fail");
    assert!(stderr.contains("\"mapping_cache\""), "{stderr}");
}

#[test]
fn eval_binary_mapping_cache_round_trips_and_knob_conflicts_are_loud() {
    let dir = std::env::temp_dir().join("harp_cli_binary_cache_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cache = dir.join("mappings.bin");
    std::fs::remove_file(&cache).ok();
    let cache_s = cache.to_string_lossy().into_owned();
    let eval = |extra: &[&str]| {
        let mut args = vec![
            "eval", "--workload", "llama2", "--machine", "hier+xnode", "--samples", "10",
            "--alloc", "search", "--json",
        ];
        args.extend_from_slice(extra);
        harp(&args)
    };

    // The .bin extension alone selects the binary spill; cold and warm
    // runs emit the byte-identical --json document.
    let (ok, plain, stderr) = eval(&[]);
    assert!(ok, "stderr: {stderr}");
    let (ok, cold, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(plain, cold, "a cold binary cache changed the --json output");
    let spilled = std::fs::read(&cache).expect("eval must spill the cache");
    assert!(spilled.starts_with(b"harp_bin"), "a .bin spill must be binary");
    let (ok, warm, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(plain, warm, "a warm binary cache changed the --json output");

    // The explicit knob agrees with the extension — fine.
    let (ok, agreed, stderr) =
        eval(&["--mapping-cache", &cache_s, "--cache-format", "binary"]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(plain, agreed);

    // The knob CONTRADICTING the extension is a loud conflict, before
    // any file is touched.
    let (ok, _, stderr) = eval(&["--mapping-cache", &cache_s, "--cache-format", "json"]);
    assert!(!ok, "a knob/extension conflict must fail the run");
    assert!(stderr.contains("cache format conflict"), "{stderr}");

    // The knob without a cache attached does nothing — reject it.
    let (ok, _, stderr) = eval(&["--cache-format", "binary"]);
    assert!(!ok, "--cache-format without --mapping-cache must fail");
    assert!(stderr.contains("does nothing without"), "{stderr}");

    // A corrupt binary spill is a loud failure, not a quiet cold cache.
    std::fs::write(&cache, b"harp_bin but then garbage").unwrap();
    let (ok, _, stderr) = eval(&["--mapping-cache", &cache_s]);
    assert!(!ok, "a corrupt binary cache must fail the run");
    assert!(stderr.contains("malformed mapping cache"), "{stderr}");

    // --config supplies the evaluation options; the flag alongside it
    // is a conflict.
    let cfg = dir.join("cfg.json");
    std::fs::write(&cfg, r#"{"workload":"bert","machine":"leaf+homo","samples":10}"#)
        .unwrap();
    let cfg_s = cfg.to_string_lossy().into_owned();
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg_s, "--cache-format", "binary"]);
    assert!(!ok, "--cache-format alongside --config must fail");
    assert!(stderr.contains("--config supplies the evaluation options"), "{stderr}");
    assert!(stderr.contains("cache_format"), "{stderr}");
}

#[test]
fn config_cache_format_knob_selects_binary_spill() {
    let dir = std::env::temp_dir().join("harp_cli_config_cache_format_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cache = dir.join("mappings.spill");
    std::fs::remove_file(&cache).ok();
    let cfg = dir.join("cfg.json");
    // A neutral extension + the config knob → binary.
    std::fs::write(
        &cfg,
        format!(
            r#"{{"workload":"bert","machine":"leaf+homo","samples":10,"alloc":"search","mapping_cache":{},"cache_format":"binary"}}"#,
            harp::util::json::Json::Str(cache.to_string_lossy().into_owned())
                .to_string_compact()
        ),
    )
    .unwrap();
    let cfg_s = cfg.to_string_lossy().into_owned();
    let (ok, stdout, stderr) = harp(&["eval", "--config", &cfg_s, "--json"]);
    assert!(ok, "stderr: {stderr}");
    harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    let spilled = std::fs::read(&cache).expect("config-driven cache must spill");
    assert!(spilled.starts_with(b"harp_bin"), "knob must select the binary format");

    // The knob without a mapping_cache key is dead — reject it.
    std::fs::write(
        &cfg,
        r#"{"workload":"bert","machine":"leaf+homo","samples":10,"cache_format":"binary"}"#,
    )
    .unwrap();
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg_s]);
    assert!(!ok, "dead cache_format knob must fail");
    assert!(stderr.contains("does nothing without"), "{stderr}");
}

#[test]
fn sweep_json_streams_parseable_ndjson() {
    let (ok, stdout, stderr) = harp(&[
        "sweep", "--workload", "bert", "--samples", "5", "--threads", "2", "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let lines: Vec<&str> = stdout.lines().filter(|l| !l.trim().is_empty()).collect();
    // 3 bandwidths × every taxonomy eval point, one object per line.
    assert_eq!(lines.len() % 3, 0, "unexpected row count: {}", lines.len());
    assert!(!lines.is_empty());
    for line in &lines {
        let v = harp::util::json::Json::parse(line).expect("each NDJSON line parses");
        assert_eq!(v.get("workload").unwrap().as_str(), Some("BERT-large"));
        assert!(v.get("machine").unwrap().as_str().is_some());
        assert!(v.get("dram_bw_bits").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("energy_pj").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("mults_per_joule").unwrap().as_f64().unwrap() > 0.0);
    }
    // The human table stays on the no-flag path, not mixed into NDJSON.
    assert!(!stdout.contains("workload: "), "table output leaked into NDJSON");
}

#[test]
fn eval_rejects_invalid_machine() {
    let (ok, _, stderr) = harp(&["eval", "--workload", "bert", "--machine", "leaf+xdepth"]);
    assert!(!ok);
    assert!(stderr.contains("unknown machine"));
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = harp(&["help"]);
    assert!(ok);
    for cmd in ["taxonomy", "classify", "topology", "eval", "figures", "sweep", "validate"] {
        assert!(stdout.contains(cmd));
    }
}

fn example_topology(name: &str) -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("topologies")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

#[test]
fn topology_prints_generated_tree() {
    let (ok, stdout, stderr) = harp(&["topology", "hier+xdepth"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("DRAM"));
    assert!(stdout.contains("near-llb"));
    assert!(stdout.contains("round-trip ok"), "{stdout}");
}

#[test]
fn topology_list_shows_every_point() {
    let (ok, stdout, _) = harp(&["topology", "list"]);
    assert!(ok);
    for id in ["leaf+homo", "leaf+intra", "hier+xnode-cl", "hier+compound"] {
        assert!(stdout.contains(id), "missing {id}:\n{stdout}");
    }
}

#[test]
fn topology_classifies_machine_file() {
    let (ok, stdout, stderr) =
        harp(&["topology", "--file", &example_topology("symphony_clustered.json")]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("cross-node (clustered)"), "{stdout}");
}

#[test]
fn topology_rejects_unknown_id() {
    let (ok, _, stderr) = harp(&["topology", "not+a-point"]);
    assert!(!ok);
    assert!(stderr.contains("unknown taxonomy id"));
}

#[test]
fn eval_topology_rejects_conflicting_bw_flags() {
    // The tree fixes the hardware: combining it with --bw must be a
    // loud error, not a silently ignored knob.
    let (ok, _, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--topology",
        &example_topology("herald_cross_node.json"),
        "--bw",
        "512",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--topology supplies the machine"), "{stderr}");
    // Same for a conflicting explicit --machine.
    let (ok, _, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--topology",
        &example_topology("herald_cross_node.json"),
        "--machine",
        "hier+xdepth",
    ]);
    assert!(!ok);
    assert!(stderr.contains("drop --machine"), "{stderr}");
}

#[test]
fn eval_runs_explicit_topology_file() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "llama2",
        "--topology",
        &example_topology("fig4h_compound.json"),
        "--samples",
        "30",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    // Three sub-accelerators reported, with busy fractions for each.
    let busy = v.get("busy_fraction").unwrap().as_arr().unwrap();
    assert_eq!(busy.len(), 3);
    // The derived class id labels the report, compound sources spelled out.
    assert_eq!(v.get("machine").unwrap().as_str(), Some("hier+compound[xnode,xdepth]"));
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (ok, _, stderr) = harp(&[]);
    assert!(!ok);
    assert!(stderr.contains("USAGE"));
}

fn example_workload(name: &str) -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("workloads")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

#[test]
fn workload_list_shows_every_registered_name() {
    let (ok, stdout, stderr) = harp(&["workload", "list"]);
    assert!(ok, "stderr: {stderr}");
    for name in ["bert", "llama2", "gpt3", "moe_decode", "resnet50", "gqa_decode", "serving_mix"]
    {
        assert!(stdout.contains(name), "missing {name}:\n{stdout}");
    }
}

#[test]
fn workload_prints_builtin_and_json_round_trips() {
    let (ok, stdout, stderr) = harp(&["workload", "moe_decode"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("expert_up"), "{stdout}");
    let (ok, stdout, _) = harp(&["workload", "moe_decode", "--json"]);
    assert!(ok);
    let doc = harp::util::json::Json::parse(&stdout).expect("valid JSON");
    let back = harp::workload::Cascade::from_json(&doc).expect("valid workload schema");
    assert_eq!(back.name, "MoE-decode");
}

#[test]
fn workload_file_validates_and_prints() {
    let (ok, stdout, stderr) =
        harp(&["workload", "--file", &example_workload("moe_decode.json")]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("router_dec0"), "{stdout}");
    // Name + --file together are a usage error, not a silent pick.
    let (ok, _, stderr) =
        harp(&["workload", "bert", "--file", &example_workload("moe_decode.json")]);
    assert!(!ok);
    assert!(stderr.contains("not both"), "{stderr}");
    // Unknown names list the remedy.
    let (ok, _, stderr) = harp(&["workload", "mamba"]);
    assert!(!ok);
    assert!(stderr.contains("unknown workload"), "{stderr}");
}

#[test]
fn eval_accepts_workload_files_and_new_families() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        &example_workload("moe_decode.json"),
        "--machine",
        "hier+xnode",
        "--samples",
        "10",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(v.get("workload").unwrap().as_str(), Some("moe-decode-example"));
    // A new built-in family through --model (the explicit built-in form).
    let (ok, stdout, stderr) = harp(&[
        "eval", "--model", "gqa_decode", "--machine", "leaf+xnode", "--samples", "10", "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert_eq!(v.get("workload").unwrap().as_str(), Some("GQA-long-decode"));
}

#[test]
fn eval_workload_model_conflicts_are_loud() {
    // --workload FILE + --model: both select the workload → error.
    let (ok, _, stderr) = harp(&[
        "eval",
        "--workload",
        &example_workload("moe_decode.json"),
        "--model",
        "bert",
        "--machine",
        "leaf+homo",
    ]);
    assert!(!ok);
    assert!(stderr.contains("not both"), "{stderr}");
    // --model only takes built-ins; a file path is a loud error.
    let (ok, _, stderr) = harp(&[
        "eval", "--model", &example_workload("moe_decode.json"), "--machine", "leaf+homo",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown built-in workload"), "{stderr}");
    // Unknown non-path workload names list the built-ins.
    let (ok, _, stderr) =
        harp(&["eval", "--workload", "mamba", "--machine", "leaf+homo"]);
    assert!(!ok);
    assert!(stderr.contains("unknown workload"), "{stderr}");
    assert!(stderr.contains("serving_mix"), "{stderr}");
}

/// The issue's acceptance gate, at the binary level: a fixed serve
/// invocation is byte-identical across HARP_THREADS=1 and 4 and across
/// two consecutive runs.
#[test]
fn serve_byte_identical_across_thread_counts_and_runs() {
    let args = [
        "serve", "--arrivals", "poisson", "--seed", "7", "--requests", "8", "--samples", "8",
    ];
    let (ok, serial, stderr) = harp_env(&args, &[("HARP_THREADS", "1")]);
    assert!(ok, "stderr: {stderr}");
    let (ok, par, stderr) = harp_env(&args, &[("HARP_THREADS", "4")]);
    assert!(ok, "stderr: {stderr}");
    let (ok, again, stderr) = harp_env(&args, &[("HARP_THREADS", "4")]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(serial, par, "HARP_THREADS changed the serve output");
    assert_eq!(par, again, "a repeat run changed the serve output");
    // The text report carries the SLO metrics.
    for needle in ["TTFT", "goodput", "throughput", "requests 8"] {
        assert!(serial.contains(needle), "missing '{needle}':\n{serial}");
    }
}

#[test]
fn serve_json_streams_parseable_ndjson() {
    let (ok, stdout, stderr) = harp(&[
        "serve", "--arrivals", "bursty", "--seed", "3", "--requests", "6", "--samples", "8",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let lines: Vec<&str> = stdout.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty());
    for line in &lines[..lines.len() - 1] {
        let v = harp::util::json::Json::parse(line).expect("each NDJSON line parses");
        assert!(v.get("id").unwrap().as_usize().is_some());
        assert!(v.get("family").unwrap().as_str().is_some());
        assert!(v.get("ttft").unwrap().as_f64().unwrap() > 0.0);
    }
    // The last line is the run summary.
    let last = harp::util::json::Json::parse(lines[lines.len() - 1]).expect("summary parses");
    let summary = last.get("summary").expect("summary object");
    assert_eq!(summary.get("requests").unwrap().as_usize(), Some(6));
    assert!(summary.get("goodput").unwrap().as_f64().is_some());
    // No text report mixed into the NDJSON stream.
    assert!(!stdout.contains("serving summary"), "text report leaked into NDJSON");
    // The default schema is pinned: the new class/page keys appear ONLY
    // behind their knobs, so default NDJSON stays byte-compatible.
    for key in
        ["\"class\"", "\"pages\"", "\"kv_page_words\"", "\"classes\"", "\"disagg\"", "\"kv_transfers\""]
    {
        assert!(!stdout.contains(key), "default NDJSON grew {key}:\n{stdout}");
    }
}

/// Class-mix and paged-booking knobs at the binary level: the report
/// grows the per-class breakdown and page line, the NDJSON records the
/// per-request class and peak pages, and the whole thing is
/// byte-identical across HARP_THREADS and repeat runs.
#[test]
fn serve_classed_paged_output_is_gated_and_deterministic() {
    let args = [
        "serve", "--arrivals", "poisson", "--seed", "7", "--requests", "8", "--samples", "8",
        "--class-mix", "interactive:1,batch:3", "--kv-page-words", "4096",
        "--slo-ttft-batch", "5e6", "--placement", "pressure",
    ];
    let (ok, serial, stderr) = harp_env(&args, &[("HARP_THREADS", "1")]);
    assert!(ok, "stderr: {stderr}");
    let (ok, par, stderr) = harp_env(&args, &[("HARP_THREADS", "4")]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(serial, par, "HARP_THREADS changed the classed serve output");
    for needle in ["class interactive", "class batch", "kv pages 4096 words each"] {
        assert!(serial.contains(needle), "missing '{needle}':\n{serial}");
    }
    // The same run as NDJSON carries the gated keys.
    let mut jargs: Vec<&str> = args.to_vec();
    jargs.push("--json");
    let (ok, stdout, stderr) = harp(&jargs);
    assert!(ok, "stderr: {stderr}");
    let lines: Vec<&str> = stdout.lines().filter(|l| !l.trim().is_empty()).collect();
    for line in &lines[..lines.len() - 1] {
        let v = harp::util::json::Json::parse(line).expect("each NDJSON line parses");
        let class = v.get("class").unwrap().as_str().unwrap().to_owned();
        assert!(class == "interactive" || class == "batch", "bad class {class}");
        assert!(v.get("pages").unwrap().as_usize().is_some());
    }
    let last = harp::util::json::Json::parse(lines[lines.len() - 1]).unwrap();
    let summary = last.get("summary").expect("summary object");
    assert_eq!(summary.get("kv_page_words").unwrap().as_usize(), Some(4096));
    assert!(summary.get("reprefill_tokens").unwrap().as_f64().is_some());
    let classes = summary.get("classes").expect("classes object");
    for c in ["interactive", "batch"] {
        let b = classes.get(c).unwrap_or_else(|| panic!("missing class {c}"));
        assert!(b.get("goodput").unwrap().as_f64().is_some());
        assert!(b.get("slo_ttft").unwrap().as_f64().is_some());
    }
    // The batch SLO actually landed (5e6, vs the interactive default).
    assert_eq!(classes.get("batch").unwrap().get("slo_ttft").unwrap().as_f64(), Some(5.0e6));
}

/// Disaggregated prefill/decode serving at the binary level: the knob
/// runs on a two-type machine, grows the gated report line, and stays
/// byte-identical across repeat runs.
#[test]
fn serve_disagg_runs_and_is_deterministic() {
    let args = [
        "serve", "--arrivals", "poisson", "--seed", "7", "--requests", "8", "--samples", "8",
        "--machine", "hier+xnode", "--disagg", "prefill=high,decode=low",
    ];
    let (ok, first, stderr) = harp(&args);
    assert!(ok, "stderr: {stderr}");
    let (ok, again, stderr) = harp(&args);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(first, again, "a repeat run changed the disagg serve output");
    assert!(first.contains("disagg prefill=high,decode=low"), "{first}");
    assert!(first.contains("hand-offs"), "{first}");
    // The NDJSON summary carries the gated keys on the same run.
    let mut jargs: Vec<&str> = args.to_vec();
    jargs.push("--json");
    let (ok, stdout, stderr) = harp(&jargs);
    assert!(ok, "stderr: {stderr}");
    let lines: Vec<&str> = stdout.lines().filter(|l| !l.trim().is_empty()).collect();
    let last = harp::util::json::Json::parse(lines[lines.len() - 1]).expect("summary parses");
    let summary = last.get("summary").expect("summary object");
    assert_eq!(summary.get("disagg").unwrap().as_str(), Some("prefill=high,decode=low"));
    assert!(summary.get("kv_transfers").unwrap().as_usize().is_some());
    assert!(summary.get("kv_transfer_words").unwrap().as_usize().is_some());
}

/// The disagg knob rejects bad specs and single-type machines loudly.
#[test]
fn serve_disagg_is_validated() {
    let (ok, _, stderr) = harp(&["serve", "--disagg", "prefill=gold,decode=low"]);
    assert!(!ok);
    assert!(stderr.contains("unknown disagg role"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--disagg", "prefill=high"]);
    assert!(!ok);
    assert!(stderr.contains("must name both phases"), "{stderr}");
    // A single-type machine has nowhere to split the two pools.
    let (ok, _, stderr) = harp(&[
        "serve", "--machine", "leaf+homo", "--disagg", "prefill=high,decode=low",
    ]);
    assert!(!ok);
    assert!(stderr.contains("at least two sub-accelerator types"), "{stderr}");
}

/// The new knobs reject bad values loudly.
#[test]
fn serve_class_and_page_knobs_are_validated() {
    let (ok, _, stderr) = harp(&["serve", "--class-mix", "gold"]);
    assert!(!ok);
    assert!(stderr.contains("unknown request class"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--placement", "wishful"]);
    assert!(!ok);
    assert!(stderr.contains("unknown placement policy"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--slo-ttft-batch", "-3"]);
    assert!(!ok);
    assert!(stderr.contains("--slo-ttft-batch must be finite and positive"), "{stderr}");
    // --class-mix is a stream-generator knob, dead with a trace.
    let (ok, _, stderr) =
        harp(&["serve", "--arrivals", "trace", "--trace", "t.json", "--class-mix", "batch"]);
    assert!(!ok);
    assert!(stderr.contains("does not apply"), "{stderr}");
}

/// Traces carry per-request classes; the engine knobs still apply.
#[test]
fn serve_trace_carries_classes() {
    let dir = std::env::temp_dir().join("harp_cli_serve_trace_class_test");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("stream.json");
    std::fs::write(
        &trace,
        r#"{"requests":[
            {"arrival": 0.0, "family": "llama2", "context": 512, "output": 16, "class": "batch"},
            {"arrival": 90000.0, "family": "llama2", "context": 256, "output": 8}
        ]}"#,
    )
    .unwrap();
    let (ok, stdout, stderr) = harp(&[
        "serve", "--arrivals", "trace", "--trace", &trace.to_string_lossy(), "--samples", "8",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("class interactive"), "{stdout}");
    assert!(stdout.contains("class batch"), "{stdout}");
    // Zero-length trace requests are a distinct, loud parse error.
    std::fs::write(
        &trace,
        r#"{"requests":[{"arrival":0,"family":"llama2","context":0,"output":8}]}"#,
    )
    .unwrap();
    let (ok, _, stderr) =
        harp(&["serve", "--arrivals", "trace", "--trace", &trace.to_string_lossy()]);
    assert!(!ok);
    assert!(stderr.contains("'context' is 0"), "{stderr}");
    std::fs::write(
        &trace,
        r#"{"requests":[{"arrival":0,"family":"llama2","context":8,"output":0}]}"#,
    )
    .unwrap();
    let (ok, _, stderr) =
        harp(&["serve", "--arrivals", "trace", "--trace", &trace.to_string_lossy()]);
    assert!(!ok);
    assert!(stderr.contains("'output' is 0"), "{stderr}");
}

#[test]
fn serve_config_supplies_the_options_and_conflicts_are_loud() {
    let dir = std::env::temp_dir().join("harp_cli_serve_config_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("cfg.json");
    std::fs::write(
        &cfg,
        r#"{"workload":"bert","machine":"hier+xnode","samples":8,
            "arrivals":{"process":"poisson","load":2.0,"requests":6,"seed":7}}"#,
    )
    .unwrap();
    let cfg_s = cfg.to_string_lossy().into_owned();
    // The config alone runs.
    let (ok, stdout, stderr) = harp(&["serve", "--config", &cfg_s]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("goodput"), "{stdout}");
    // Any stream knob alongside --config is a conflict, not a shadow.
    for extra in [
        ["--arrivals", "bursty"],
        ["--load", "4"],
        ["--seed", "9"],
        ["--machine", "leaf+homo"],
        ["--disagg", "prefill=high,decode=low"],
    ] {
        let (ok, _, stderr) = harp(&["serve", "--config", &cfg_s, extra[0], extra[1]]);
        assert!(!ok, "{} alongside --config must fail", extra[0]);
        assert!(stderr.contains("--config supplies the serving options"), "{stderr}");
    }
    // A config without an "arrivals" object cannot serve.
    let plain = dir.join("plain.json");
    std::fs::write(&plain, r#"{"workload":"bert","machine":"hier+xnode","samples":8}"#)
        .unwrap();
    let (ok, _, stderr) = harp(&["serve", "--config", &plain.to_string_lossy()]);
    assert!(!ok, "serve without arrivals must fail");
    assert!(stderr.contains("\"arrivals\""), "{stderr}");
    // And eval rejects a config that has one — the key is serve-only.
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg_s]);
    assert!(!ok, "eval with an arrivals key must fail");
    assert!(stderr.contains("only applies to 'harp serve'"), "{stderr}");
}

#[test]
fn serve_rejects_unknown_process_and_dead_knobs() {
    let (ok, _, stderr) = harp(&["serve", "--arrivals", "sinusoid"]);
    assert!(!ok);
    assert!(stderr.contains("unknown arrival process"), "{stderr}");
    assert!(stderr.contains("poisson, bursty, trace"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--trace", "t.json"]);
    assert!(!ok, "--trace without --arrivals trace must fail");
    assert!(stderr.contains("does nothing without"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--arrivals", "trace"]);
    assert!(!ok, "--arrivals trace without --trace must fail");
    assert!(stderr.contains("requires --trace"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--arrivals", "trace", "--trace", "t.json", "--load", "4"]);
    assert!(!ok, "--load with a trace must fail");
    assert!(stderr.contains("does not apply"), "{stderr}");
    let (ok, _, stderr) = harp(&["serve", "--workload-mix", "bert"]);
    assert!(!ok);
    assert!(stderr.contains("unknown request family"), "{stderr}");
}

#[test]
fn serve_runs_a_trace_file() {
    let dir = std::env::temp_dir().join("harp_cli_serve_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("stream.json");
    std::fs::write(
        &trace,
        r#"{"requests":[
            {"arrival": 0.0, "family": "llama2", "context": 512, "output": 16},
            {"arrival": 90000.0, "family": "llama2", "context": 256, "output": 8}
        ]}"#,
    )
    .unwrap();
    let (ok, stdout, stderr) = harp(&[
        "serve", "--arrivals", "trace", "--trace", &trace.to_string_lossy(), "--samples", "8",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("requests 2"), "{stdout}");
    assert!(stdout.contains("completed 2"), "{stdout}");
    // A malformed trace is a loud, file-labelled error.
    std::fs::write(&trace, r#"{"requests":[{"arrival":0}]}"#).unwrap();
    let (ok, _, stderr) = harp(&[
        "serve", "--arrivals", "trace", "--trace", &trace.to_string_lossy(),
    ]);
    assert!(!ok);
    assert!(stderr.contains("'family' must be a string"), "{stderr}");
}

#[test]
fn eval_config_rejects_cli_workload_selectors() {
    let dir = std::env::temp_dir().join("harp_cli_config_workload_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("cfg.json");
    std::fs::write(
        &cfg,
        r#"{"workload":"bert","machine":"leaf+homo","samples":10}"#,
    )
    .unwrap();
    let cfg = cfg.to_string_lossy().into_owned();
    for flag in ["--workload", "--model"] {
        let (ok, _, stderr) = harp(&["eval", "--config", &cfg, flag, "bert"]);
        assert!(!ok, "{flag} alongside --config must fail");
        assert!(stderr.contains("--config supplies the workload"), "{flag}: {stderr}");
    }
    // The config alone still runs.
    let (ok, _, stderr) = harp(&["eval", "--config", &cfg, "--json"]);
    assert!(ok, "stderr: {stderr}");
}
