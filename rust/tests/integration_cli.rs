//! Integration: the `harp` binary's CLI surface.

use std::process::Command;

fn harp(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_harp"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn taxonomy_prints_table1() {
    let (ok, stdout, _) = harp(&["taxonomy"]);
    assert!(ok);
    for name in ["TPUv1", "NeuPIM", "Symphony", "Herald"] {
        assert!(stdout.contains(name), "missing {name}");
    }
}

#[test]
fn classify_known_work() {
    let (ok, stdout, _) = harp(&["classify", "duplex"]);
    assert!(ok);
    assert!(stdout.contains("cross-depth"));
}

#[test]
fn classify_unknown_fails() {
    let (ok, _, stderr) = harp(&["classify", "not-an-accelerator"]);
    assert!(!ok);
    assert!(stderr.contains("no prior work"));
}

#[test]
fn eval_emits_json() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--machine",
        "leaf+xnode",
        "--samples",
        "60",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(v.get("machine").unwrap().as_str(), Some("leaf+xnode"));
}

#[test]
fn eval_contention_flag_flows_to_report() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "llama2",
        "--machine",
        "hier+xnode",
        "--samples",
        "20",
        "--contention",
        "on",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    // hier+xnode shares its low LLB between two units: the occupancy
    // report must list that node (plus the root) with 2 and 3 users.
    let nodes = v.get("node_contention").unwrap().as_arr().unwrap();
    assert!(
        nodes
            .iter()
            .any(|c| c.get("node").unwrap().as_str() == Some("llb.low")
                && c.get("users").unwrap().as_usize() == Some(2)),
        "{stdout}"
    );
    // An unknown mode is a usage error, not a silent default.
    let (ok, _, stderr) = harp(&[
        "eval", "--workload", "bert", "--machine", "leaf+xnode", "--contention", "sometimes",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown contention mode"), "{stderr}");
}

#[test]
fn eval_rejects_invalid_machine() {
    let (ok, _, stderr) = harp(&["eval", "--workload", "bert", "--machine", "leaf+xdepth"]);
    assert!(!ok);
    assert!(stderr.contains("unknown machine"));
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = harp(&["help"]);
    assert!(ok);
    for cmd in ["taxonomy", "classify", "topology", "eval", "figures", "sweep", "validate"] {
        assert!(stdout.contains(cmd));
    }
}

fn example_topology(name: &str) -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("topologies")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

#[test]
fn topology_prints_generated_tree() {
    let (ok, stdout, stderr) = harp(&["topology", "hier+xdepth"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("DRAM"));
    assert!(stdout.contains("near-llb"));
    assert!(stdout.contains("round-trip ok"), "{stdout}");
}

#[test]
fn topology_list_shows_every_point() {
    let (ok, stdout, _) = harp(&["topology", "list"]);
    assert!(ok);
    for id in ["leaf+homo", "leaf+intra", "hier+xnode-cl", "hier+compound"] {
        assert!(stdout.contains(id), "missing {id}:\n{stdout}");
    }
}

#[test]
fn topology_classifies_machine_file() {
    let (ok, stdout, stderr) =
        harp(&["topology", "--file", &example_topology("symphony_clustered.json")]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("cross-node (clustered)"), "{stdout}");
}

#[test]
fn topology_rejects_unknown_id() {
    let (ok, _, stderr) = harp(&["topology", "not+a-point"]);
    assert!(!ok);
    assert!(stderr.contains("unknown taxonomy id"));
}

#[test]
fn eval_topology_rejects_conflicting_bw_flags() {
    // The tree fixes the hardware: combining it with --bw must be a
    // loud error, not a silently ignored knob.
    let (ok, _, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--topology",
        &example_topology("herald_cross_node.json"),
        "--bw",
        "512",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--topology supplies the machine"), "{stderr}");
    // Same for a conflicting explicit --machine.
    let (ok, _, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--topology",
        &example_topology("herald_cross_node.json"),
        "--machine",
        "hier+xdepth",
    ]);
    assert!(!ok);
    assert!(stderr.contains("drop --machine"), "{stderr}");
}

#[test]
fn eval_runs_explicit_topology_file() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "llama2",
        "--topology",
        &example_topology("fig4h_compound.json"),
        "--samples",
        "30",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    // Three sub-accelerators reported, with busy fractions for each.
    let busy = v.get("busy_fraction").unwrap().as_arr().unwrap();
    assert_eq!(busy.len(), 3);
    // The derived class id labels the report, compound sources spelled out.
    assert_eq!(v.get("machine").unwrap().as_str(), Some("hier+compound[xnode,xdepth]"));
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (ok, _, stderr) = harp(&[]);
    assert!(!ok);
    assert!(stderr.contains("USAGE"));
}
