//! Integration: the `harp` binary's CLI surface.

use std::process::Command;

fn harp(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_harp"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn taxonomy_prints_table1() {
    let (ok, stdout, _) = harp(&["taxonomy"]);
    assert!(ok);
    for name in ["TPUv1", "NeuPIM", "Symphony", "Herald"] {
        assert!(stdout.contains(name), "missing {name}");
    }
}

#[test]
fn classify_known_work() {
    let (ok, stdout, _) = harp(&["classify", "duplex"]);
    assert!(ok);
    assert!(stdout.contains("cross-depth"));
}

#[test]
fn classify_unknown_fails() {
    let (ok, _, stderr) = harp(&["classify", "not-an-accelerator"]);
    assert!(!ok);
    assert!(stderr.contains("no prior work"));
}

#[test]
fn eval_emits_json() {
    let (ok, stdout, stderr) = harp(&[
        "eval",
        "--workload",
        "bert",
        "--machine",
        "leaf+xnode",
        "--samples",
        "60",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let v = harp::util::json::Json::parse(&stdout).expect("valid JSON output");
    assert!(v.get("latency_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(v.get("machine").unwrap().as_str(), Some("leaf+xnode"));
}

#[test]
fn eval_rejects_invalid_machine() {
    let (ok, _, stderr) = harp(&["eval", "--workload", "bert", "--machine", "leaf+xdepth"]);
    assert!(!ok);
    assert!(stderr.contains("unknown machine"));
}

#[test]
fn help_lists_commands() {
    let (ok, stdout, _) = harp(&["help"]);
    assert!(ok);
    for cmd in ["taxonomy", "classify", "eval", "figures", "sweep", "validate"] {
        assert!(stdout.contains(cmd));
    }
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (ok, _, stderr) = harp(&[]);
    assert!(!ok);
    assert!(stderr.contains("USAGE"));
}
