"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (and block configurations for the GEMM) —
the CORE correctness signal for the AOT pipeline.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.gemm import gemm, pick_block
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------- GEMM

dims = st.sampled_from([1, 2, 3, 4, 8, 16, 17, 32, 64, 96, 128, 256])


@settings(max_examples=40, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_gemm_matches_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1)
    got = gemm(x, w)
    want = ref.gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    bm=st.sampled_from([16, 32, 64, 128]),
    bn=st.sampled_from([16, 32, 64, 128]),
    bk=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_block_shape_invariant(bm, bn, bk, seed):
    """The result must not depend on the BlockSpec tiling (the functional
    twin of 'cost model statistics change, numerics do not')."""
    x = rand((128, 256), seed)
    w = rand((256, 64), seed + 1)
    base = gemm(x, w)
    tiled = gemm(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(base), rtol=2e-4, atol=2e-4)


def test_gemm_rejects_bad_contraction():
    with pytest.raises(AssertionError):
        gemm(rand((4, 8), 0), rand((9, 4), 1))


def test_pick_block_divides():
    for dim in [1, 7, 96, 128, 3000]:
        for target in [1, 16, 128, 512]:
            b = pick_block(dim, target)
            assert dim % b == 0
            assert b <= max(target, 1)


# ----------------------------------------------------------- Attention

small = st.sampled_from([1, 2, 3, 4, 8])
lens = st.sampled_from([1, 2, 5, 16, 33, 64, 96])
hdims = st.sampled_from([4, 8, 16, 32, 64])


@settings(max_examples=40, deadline=None)
@given(b=small, s=lens, t=lens, dh=hdims, seed=st.integers(0, 2**31 - 1))
def test_attention_matches_ref(b, s, t, dh, seed):
    q = rand((b, s, dh), seed)
    k = rand((b, t, dh), seed + 1)
    v = rand((b, t, dh), seed + 2)
    got = attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_attention_rows_are_convex_combinations():
    """Softmax weights sum to 1 ⇒ each output row lies inside the convex
    hull of the V rows (value-range sanity independent of the oracle)."""
    q = rand((2, 8, 16), 0)
    k = rand((2, 32, 16), 1)
    v = rand((2, 32, 16), 2)
    out = np.asarray(attention(q, k, v))
    vmin = np.asarray(v).min(axis=1, keepdims=True)
    vmax = np.asarray(v).max(axis=1, keepdims=True)
    assert (out >= vmin - 1e-4).all()
    assert (out <= vmax + 1e-4).all()


def test_attention_is_permutation_invariant_over_kv():
    """Softmax-attention is invariant to permuting KV positions."""
    q = rand((1, 4, 8), 0)
    k = rand((1, 16, 8), 1)
    v = rand((1, 16, 8), 2)
    perm = np.random.default_rng(3).permutation(16)
    base = np.asarray(attention(q, k, v))
    shuf = np.asarray(attention(q, k[:, perm], v[:, perm]))
    np.testing.assert_allclose(shuf, base, rtol=1e-4, atol=1e-5)
