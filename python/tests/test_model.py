"""L2 correctness: model layers compose the kernels correctly."""

import jax.numpy as jnp
import numpy as np
from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * 0.05)


def make_weights(d, f, seed=0):
    return dict(
        wq=rand((d, d), seed),
        wk=rand((d, d), seed + 1),
        wv=rand((d, d), seed + 2),
        wo=rand((d, d), seed + 3),
        w1=rand((d, f), seed + 4),
        w2=rand((f, d), seed + 5),
    )


def encoder_ref(x, w, heads):
    s, d = x.shape
    dh = d // heads
    q, k, v = (ref.gemm_ref(x, w[n]) for n in ("wq", "wk", "wv"))
    split = lambda t: t.reshape(s, heads, dh).transpose(1, 0, 2)
    ctx = ref.attention_ref(split(q), split(k), split(v))
    ctx = ctx.transpose(1, 0, 2).reshape(s, d)
    return ref.gemm_ref(ref.gemm_ref(ref.gemm_ref(ctx, w["wo"]), w["w1"]), w["w2"])


def test_encoder_layer_shape_and_numerics():
    d, s, f, heads = 64, 32, 128, 4
    w = make_weights(d, f)
    x = rand((s, d), 99)
    out = model.encoder_layer(x, w["wq"], w["wk"], w["wv"], w["wo"], w["w1"], w["w2"], heads=heads)
    assert out.shape == (s, d)
    want = encoder_ref(x, w, heads)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_decode_step_extends_cache():
    d, f, heads, t = 64, 128, 4, 16
    w = make_weights(d, f, seed=7)
    x = rand((1, d), 5)
    kc, vc = rand((t, d), 6), rand((t, d), 8)
    out, k_new, v_new = model.decode_step(
        x, kc, vc, w["wq"], w["wk"], w["wv"], w["wo"], w["w1"], w["w2"], heads=heads
    )
    assert out.shape == (1, d)
    assert k_new.shape == (t + 1, d)
    assert v_new.shape == (t + 1, d)
    # Cache prefix is preserved.
    np.testing.assert_array_equal(np.asarray(k_new[:t]), np.asarray(kc))


def test_autoregressive_decode_loop():
    """Run several decode steps; outputs stay finite and the cache grows —
    the functional mirror of the analytical decode chunking."""
    d, f, heads = 64, 128, 4
    w = make_weights(d, f, seed=11)
    x = rand((1, d), 1)
    kc, vc = rand((4, d), 2), rand((4, d), 3)
    for step in range(5):
        x, kc, vc = model.decode_step(
            x, kc, vc, w["wq"], w["wk"], w["wv"], w["wo"], w["w1"], w["w2"], heads=heads
        )
        assert np.isfinite(np.asarray(x)).all(), f"NaN at step {step}"
    assert kc.shape[0] == 9


def test_decode_step_flat_matches_full():
    d, f = 256, 512
    w = make_weights(d, f, seed=13)
    x = rand((1, d), 4)
    kc, vc = rand((8, d), 5), rand((8, d), 6)
    full, _, _ = model.decode_step(
        x, kc, vc, w["wq"], w["wk"], w["wv"], w["wo"], w["w1"], w["w2"], heads=4
    )
    flat = model.decode_step_flat(x, kc, vc, w["wq"], w["wk"], w["wv"], w["wo"], w["w1"], w["w2"])
    np.testing.assert_allclose(np.asarray(flat), np.asarray(full), rtol=1e-5)
