"""AOT pipeline: deterministic inputs, HLO text lowering, manifest."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot


def test_input_pattern_matches_rust_formula():
    """Must equal rust/src/runtime/mod.rs::input_value exactly."""
    a = aot.input_array(0, (251,))
    assert a.dtype == np.float32
    assert a[0] == np.float32(-125.0 / 251.0)
    assert a[125] == 0.0
    assert a[250] == np.float32(125.0 / 251.0)
    # Periodicity and offset behaviour.
    b = aot.input_array(1, (4,))
    off = (1 * aot.INPUT_STRIDE) % 251
    assert b[0] == np.float32(((off % 251) - 125.0) / 251.0)


def test_hlo_text_is_parseable_hlo():
    text = aot.to_hlo_text(
        lambda x, y: x @ y,
        [jax.ShapeDtypeStruct((4, 4), jnp.float32)] * 2,
    )
    assert "HloModule" in text
    assert "f32[4,4]" in text
    # Single-output functions lower to a 1-tuple (return_tuple=True).
    assert "ROOT tuple" in text


def test_artifact_defs_cover_all_layers():
    names = [n for n, _, _ in aot.artifact_defs()]
    assert names == ["gemm", "attention", "encoder_layer", "decode_step"]


def test_manifest_on_disk_if_built():
    """If `make artifacts` ran, the manifest must be consistent."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        import pytest

        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"gemm", "attention", "encoder_layer", "decode_step"}
    for a in manifest["artifacts"]:
        hlo = os.path.join(os.path.dirname(path), a["file"])
        assert os.path.exists(hlo), a["file"]
        assert np.isfinite(a["golden_sum"])
        assert all(len(i["shape"]) >= 1 for i in a["inputs"])
