"""AOT build step: lower the L2 model (+ L1 kernels) to HLO text.

Run via `make artifacts` (python -m compile.aot --out ../artifacts).

Emits one `<name>.hlo.txt` per artifact plus `manifest.json` describing
input shapes and golden output statistics on the deterministic input
pattern shared with the Rust runtime (`rust/src/runtime/mod.rs`):

    val(i) = ((i mod 251) - 125) / 251        (exact in f32)
    input j uses indices offset by j · 1_000_003

HLO *text* (never `.serialize()`): jax ≥ 0.5 emits protos with 64-bit
instruction ids that the crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels.attention import attention
from .kernels.gemm import gemm
from .kernels import ref

INPUT_STRIDE = 1_000_003


def input_array(idx: int, shape) -> np.ndarray:
    """Deterministic input j for an artifact (matches the Rust side)."""
    n = int(np.prod(shape))
    i = np.arange(n, dtype=np.uint64) + np.uint64(idx * INPUT_STRIDE)
    vals = ((i % 251).astype(np.float32) - 125.0) / 251.0
    return vals.reshape(shape)


def to_hlo_text(fn, example_args) -> str:
    """jit → lower → stablehlo → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Artifact-friendly model dimensions: small enough to compile and run in
# seconds under interpret-mode lowering, large enough to exercise every
# block of the kernels (multiple grid steps in each dimension).
D_MODEL = 256
HEADS = 4
SEQ = 128
KV = 96
D_FF = 512


def artifact_defs():
    """(name, fn, input shapes) for every artifact."""
    d, s, f, kv = D_MODEL, SEQ, D_FF, KV
    dh = d // HEADS
    sq = lambda: (d, d)
    return [
        (
            "gemm",
            lambda x, w: gemm(x, w),
            [(s, d), (d, f)],
        ),
        (
            "attention",
            lambda q, k, v: attention(q, k, v),
            [(HEADS, s, dh), (HEADS, kv, dh), (HEADS, kv, dh)],
        ),
        (
            "encoder_layer",
            model.encoder_layer_flat,
            [(s, d), sq(), sq(), sq(), sq(), (d, f), (f, d)],
        ),
        (
            "decode_step",
            model.decode_step_flat,
            [(1, d), (kv, d), (kv, d), sq(), sq(), sq(), sq(), (d, f), (f, d)],
        ),
    ]


def reference_output(name, inputs):
    """Golden output via the pure-jnp oracles (independent of Pallas)."""
    if name == "gemm":
        return ref.gemm_ref(*inputs)
    if name == "attention":
        return ref.attention_ref(*inputs)
    if name == "encoder_layer":
        x, wq, wk, wv, wo, w1, w2 = inputs
        s, d = x.shape
        dh = d // HEADS
        q, k, v = ref.gemm_ref(x, wq), ref.gemm_ref(x, wk), ref.gemm_ref(x, wv)
        split = lambda t: t.reshape(s, HEADS, dh).transpose(1, 0, 2)
        ctx = ref.attention_ref(split(q), split(k), split(v))
        ctx = ctx.transpose(1, 0, 2).reshape(s, d)
        return ref.gemm_ref(ref.gemm_ref(ref.gemm_ref(ctx, wo), w1), w2)
    if name == "decode_step":
        x, kc, vc, wq, wk, wv, wo, w1, w2 = inputs
        _, d = x.shape
        dh = d // HEADS
        q = ref.gemm_ref(x, wq)
        k_all = jnp.concatenate([kc, ref.gemm_ref(x, wk)], axis=0)
        v_all = jnp.concatenate([vc, ref.gemm_ref(x, wv)], axis=0)
        t = k_all.shape[0]
        split_kv = lambda m: m.reshape(t, HEADS, dh).transpose(1, 0, 2)
        ctx = ref.attention_ref(
            q.reshape(1, HEADS, dh).transpose(1, 0, 2), split_kv(k_all), split_kv(v_all)
        )
        ctx = ctx.transpose(1, 0, 2).reshape(1, d)
        return ref.gemm_ref(ref.gemm_ref(ref.gemm_ref(ctx, wo), w1), w2)
    raise ValueError(name)


def main() -> None:
    ap = argparse.ArgumentParser(description="HARP AOT artifact builder")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": []}
    for name, fn, shapes in artifact_defs():
        inputs = [jnp.asarray(input_array(j, s)) for j, s in enumerate(shapes)]
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        text = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)

        golden = np.asarray(reference_output(name, inputs), dtype=np.float64)
        # Also check the kernel path agrees with the oracle at build time
        # (the core L1-vs-ref correctness gate of the AOT pipeline).
        kernel_out = np.asarray(fn(*inputs), dtype=np.float64)
        np.testing.assert_allclose(kernel_out, golden, rtol=5e-4, atol=5e-4)

        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [{"shape": list(s), "dtype": "f32"} for s in shapes],
                "golden_sum": float(golden.sum()),
                "golden_absmax": float(np.abs(golden).max()),
            }
        )
        print(f"wrote {fname}: {len(text)} chars, golden_sum={golden.sum():.6f}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
