"""L1 Pallas kernel: fused logit→softmax→attend — the low-reuse operator.

One grid step processes one (batch · head) slice: P = Q·Kᵀ/√dh,
softmax over the KV axis, O = softmax(P)·V. Fusing the three einsums
keeps the S×S logit tile in VMEM — the on-chip staging of intermediate
tiles that inter-operator fusion papers (and HARP's low-reuse
sub-accelerator) exploit. interpret=True for CPU-PJRT execution.

TPU estimate (DESIGN.md §Hardware-Adaptation): with S = 128, dh = 64 at
f32, per-step VMEM = Q + K + V + P + O ≈ (3·128·64 + 128·128 + 128·64)
· 4 B ≈ 0.19 MB; the dh = 64 contraction half-fills a 128-lane MXU —
the structural reason attention underuses big arrays, i.e. the paper's
motivation for a separate narrow low-reuse unit.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0]  # [S, dh]
    k = k_ref[0]  # [T, dh]
    v = v_ref[0]  # [T, dh]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [S, T]
    # Numerically-stable softmax over the KV axis.
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q, k, v):
    """Batched fused attention via a Pallas kernel (interpret mode).

    q: [B, S, dh], k: [B, T, dh], v: [B, T, dh] → [B, S, dh], float32.
    B is the (batch · head) axis; T the KV length.
    """
    b, s, dh = q.shape
    _, t, _ = k.shape
    scale = 1.0 / math.sqrt(dh)
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
