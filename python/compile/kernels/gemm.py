"""L1 Pallas kernel: blocked GEMM — the sub-accelerator datapath.

The BlockSpec grid is the functional twin of a HARP mapping: the
(BM, BN, BK) block shape plays the role of the LLB/L1 tiling factors and
the grid loops are the DRAM-level temporal loops (K innermost, so the
output block stays resident across the reduction — the same
output-stationary blocking the Rust mapper's balanced heuristic finds).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT client cannot execute. On a real TPU the block shape below
(128×128×512 at f32) has a VMEM footprint of
(128·512 + 512·128 + 128·128)·4 B ≈ 0.59 MB — comfortably inside 16 MB
VMEM with room for double buffering, and the 128-wide blocks keep the
MXU systolic array fully fed (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (BM, BN) output block; grid dim 2 iterates the K blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    del n_k  # grid bound is encoded in the call, kept for clarity


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is ≤ `target` (block shapes must
    tile the problem exactly; transformer dims are powers of two)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(x, w, bm: int = 128, bn: int = 128, bk: int = 512):
    """Blocked GEMM `x @ w` via a Pallas kernel (interpret mode).

    x: [M, K], w: [K, N] → [M, N] (all float32).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)
