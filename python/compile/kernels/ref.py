"""Pure-jnp correctness oracles for the Pallas kernels.

These define the semantics the kernels must match; pytest (and the AOT
manifest goldens) compare against them.
"""

import math

import jax.numpy as jnp


def gemm_ref(x, w):
    """x: [M, K] @ w: [K, N] in float32."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def attention_ref(q, k, v):
    """q: [B, S, dh], k/v: [B, T, dh] → [B, S, dh]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bsd,btd->bst", q, k) * scale
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bst,btd->bsd", p, v).astype(jnp.float32)
