"""L2: the transformer layers in JAX, composed from the L1 kernels.

These are the *functional* twins of the analytical workloads in
`rust/src/workload/transformer.rs` — the same einsum cascade
(Q,K,V → logit → softmax → attend → deproj → FFN), at artifact-friendly
sizes. `make artifacts` lowers them to HLO text; the Rust coordinator
executes them through PJRT to validate that the cascades the cost model
reasons about correspond to real, numerically-correct computation.

Everything is pure f32 and built from the two Pallas kernels:
`kernels.gemm` (high-reuse datapath) + `kernels.attention` (low-reuse).
"""

from .kernels.attention import attention
from .kernels.gemm import gemm


def encoder_layer(x, wq, wk, wv, wo, w1, w2, *, heads: int):
    """One encoder attention + FFN layer (the BERT cascade).

    x: [S, D]; wq/wk/wv/wo: [D, D]; w1: [D, F]; w2: [F, D] → [S, D].
    """
    s, d = x.shape
    dh = d // heads

    q = gemm(x, wq)  # q_gen
    k = gemm(x, wk)  # k_gen
    v = gemm(x, wv)  # v_gen

    # [S, D] → [H, S, dh] for the batched attention kernel.
    split = lambda t: t.reshape(s, heads, dh).transpose(1, 0, 2)
    ctx = attention(split(q), split(k), split(v))  # logit+softmax+attend
    ctx = ctx.transpose(1, 0, 2).reshape(s, d)

    y = gemm(ctx, wo)  # deproj
    h = gemm(y, w1)  # ffn1
    return gemm(h, w2)  # ffn2


def decode_step(x, k_cache, v_cache, wq, wk, wv, wo, w1, w2, *, heads: int):
    """One autoregressive decode step with a KV cache (the low-reuse
    phase of the GPT/Llama cascade).

    x: [1, D] (current token), k_cache/v_cache: [T, D] (past keys/values).
    Returns (y: [1, D], k_new: [T+1, D], v_new: [T+1, D]).
    """
    import jax.numpy as jnp

    _, d = x.shape
    dh = d // heads

    q = gemm(x, wq)
    k_tok = gemm(x, wk)
    v_tok = gemm(x, wv)
    k_all = jnp.concatenate([k_cache, k_tok], axis=0)  # [T+1, D]
    v_all = jnp.concatenate([v_cache, v_tok], axis=0)

    t = k_all.shape[0]
    split_q = q.reshape(1, heads, dh).transpose(1, 0, 2)  # [H, 1, dh]
    split_kv = lambda m: m.reshape(t, heads, dh).transpose(1, 0, 2)
    ctx = attention(split_q, split_kv(k_all), split_kv(v_all))  # [H, 1, dh]
    ctx = ctx.transpose(1, 0, 2).reshape(1, d)

    y = gemm(ctx, wo)
    h = gemm(y, w1)
    out = gemm(h, w2)
    return out, k_all, v_all


def encoder_layer_flat(x, wq, wk, wv, wo, w1, w2):
    """4-head encoder layer with a single tensor output (AOT target)."""
    return encoder_layer(x, wq, wk, wv, wo, w1, w2, heads=4)


def decode_step_flat(x, k_cache, v_cache, wq, wk, wv, wo, w1, w2):
    """Decode step returning only the new token embedding (AOT target —
    single output keeps the HLO interchange tuple trivial)."""
    out, _, _ = decode_step(
        x, k_cache, v_cache, wq, wk, wv, wo, w1, w2, heads=4
    )
    return out
