//! Intra-cascade partitioning study: BERT-large (paper §II-B, §V-A and
//! the Fig 6 utilisation zoom).
//!
//! Shows why the homogeneous machine wins the encoder workload: the
//! dependency graph only lets V-generation overlap the logit BMM, so a
//! heterogeneous split leaves the high-reuse unit idle during the
//! attention block while its GEMMs are starved of bandwidth.
//!
//! Run: `cargo run --release --example bert_intra_cascade`

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::util::table::Table;
use harp::workload::transformer;

fn main() {
    let wl = transformer::bert_large();
    let cascade = transformer::encoder_cascade(&wl);
    let opts = EvalOptions { samples: 400, ..EvalOptions::default() };

    println!("workload: {} (intra-cascade partitioning)\n", wl.name);
    println!("{}", cascade.describe());

    // Where each op lands on the cross-node machine, and what it costs.
    let class = HarpClass::from_id("leaf+xnode").unwrap();
    let params = HardwareParams::default();
    let r = evaluate_cascade_on_config(&class, &params, &cascade, &opts).unwrap();
    let mut t = Table::new(&["op", "sub-accelerator", "cycles", "bound", "PE util"]);
    for m in &r.mapped {
        let op = &cascade.ops[m.op_index];
        let sub = &r.machine.sub_accels[m.sub_accel];
        t.row(&[
            op.name.clone(),
            format!("{} ({})", sub.spec.name, sub.role.name()),
            format!("{:.3e}", m.stats.cycles * op.count as f64),
            m.stats.bound.name(),
            format!("{:.0}%", m.stats.utilization * 100.0),
        ]);
    }
    println!("operation placement on leaf+cross-node:\n{}", t.render());

    // Homogeneous vs heterogeneous at both bandwidth points.
    let mut cmp = Table::new(&["machine", "bw b/cyc", "latency", "speedup vs homo", "energy µJ"]);
    for bw in [2048.0, 512.0] {
        let params = HardwareParams { dram_bw_bits: bw, ..HardwareParams::default() };
        let base = evaluate_cascade_on_config(
            &HarpClass::from_id("leaf+homo").unwrap(),
            &params,
            &cascade,
            &opts,
        )
        .unwrap();
        for id in ["leaf+homo", "leaf+xnode", "leaf+intra", "hier+xdepth"] {
            let r = evaluate_cascade_on_config(
                &HarpClass::from_id(id).unwrap(),
                &params,
                &cascade,
                &opts,
            )
            .unwrap();
            cmp.row(&[
                id.into(),
                format!("{bw}"),
                format!("{:.3e}", r.stats.latency_cycles),
                format!("{:.3}", base.stats.latency_cycles / r.stats.latency_cycles),
                format!("{:.1}", r.stats.energy_pj * 1e-6),
            ]);
        }
    }
    println!("{}", cmp.render());

    // The utilisation-over-time zoom (Fig 6 inset): homo keeps the whole
    // array busy through the GEMMs but idles in the attention block; the
    // heterogeneous machine's high-reuse unit waits on the low-reuse one.
    for id in ["leaf+homo", "leaf+xnode"] {
        let r = evaluate_cascade_on_config(
            &HarpClass::from_id(id).unwrap(),
            &params,
            &cascade,
            &opts,
        )
        .unwrap();
        let tl = &r.stats.utilization_timeline;
        print!("{id:<12} |");
        for v in tl.iter() {
            let c = match (v * 8.0) as u32 {
                0 => ' ',
                1 => '▁',
                2 => '▂',
                3 => '▃',
                4 => '▄',
                5 => '▅',
                6 => '▆',
                7 => '▇',
                _ => '█',
            };
            print!("{c}");
        }
        println!("| PE-weighted utilisation over time");
    }
    println!("\nbert_intra_cascade OK");
}
