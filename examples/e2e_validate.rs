//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! 1. Loads the AOT artifacts (L2 JAX model calling L1 Pallas kernels,
//!    lowered to HLO text by `make artifacts`) into the PJRT runtime.
//! 2. Validates numerics against the python oracle goldens.
//! 3. Serves a batch of requests through the REAL encoder layer and an
//!    autoregressive decode loop, reporting latency and throughput —
//!    the serving-style measurement for the functional twin of the
//!    analytical workloads.
//! 4. Evaluates the SAME small-model cascade in the analytical HARP
//!    framework (L3) and reports the predicted machine cycles next to
//!    the functional measurement, proving the layers describe one
//!    consistent workload.
//!
//! Run: `make artifacts && cargo run --release --example e2e_validate`

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::runtime::client::Runtime;
use harp::runtime::validate::{render_reports, validate_all};
use harp::util::table::Table;
use harp::workload::cascade::Cascade;
use harp::workload::einsum::{Phase, TensorOp};
use std::path::Path;

/// The artifact model's dimensions (mirrors python/compile/aot.py).
const D: u64 = 256;
const HEADS: u64 = 4;
const SEQ: u64 = 128;
const KV: u64 = 96;
const D_FF: u64 = 512;

/// The artifact encoder layer as an analytical cascade.
fn artifact_encoder_cascade() -> Cascade {
    let mut g = Cascade::new("artifact-encoder");
    let dh = D / HEADS;
    let q = g.push(TensorOp::gemm("q_gen", Phase::Encoder, SEQ, D, D));
    let k = g.push(TensorOp::gemm("k_gen", Phase::Encoder, SEQ, D, D));
    let v = g.push(TensorOp::gemm("v_gen", Phase::Encoder, SEQ, D, D));
    let logit = g.push(TensorOp::bmm("logit", Phase::Encoder, HEADS, SEQ, dh, SEQ));
    let softmax = g.push(TensorOp::vector("softmax", Phase::Encoder, HEADS, SEQ, SEQ));
    let attend = g.push(TensorOp::bmm("attend", Phase::Encoder, HEADS, SEQ, SEQ, dh));
    let deproj = g.push(TensorOp::gemm("deproj", Phase::Encoder, SEQ, D, D));
    let ffn1 = g.push(TensorOp::gemm("ffn1", Phase::Encoder, SEQ, D, D_FF));
    let ffn2 = g.push(TensorOp::gemm("ffn2", Phase::Encoder, SEQ, D_FF, D));
    for (a, b) in [
        (q, logit),
        (k, logit),
        (logit, softmax),
        (softmax, attend),
        (v, attend),
        (attend, deproj),
        (deproj, ffn1),
        (ffn1, ffn2),
    ] {
        g.dep(a, b);
    }
    g.validate().unwrap();
    g
}

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // --- 1+2: load and validate numerics --------------------------------
    println!("== numeric validation against python oracle goldens ==");
    let reports = validate_all(dir).expect("artifacts load and run");
    println!("{}", render_reports(&reports));
    assert!(reports.iter().all(|r| r.ok), "numeric validation failed");

    // --- 3: serve requests through the real model ------------------------
    let rt = Runtime::load(dir).unwrap();
    println!("== serving measurement (PJRT CPU, interpret-lowered Pallas kernels) ==");
    let mut t = Table::new(&["stage", "mean latency", "throughput"]);
    let enc_us = rt.bench("encoder_layer", 12).unwrap();
    t.row(&[
        format!("encoder prefill ({SEQ} tokens)"),
        format!("{:.2} ms", enc_us / 1e3),
        format!("{:.0} tok/s", SEQ as f64 / (enc_us * 1e-6)),
    ]);
    let dec_us = rt.bench("decode_step", 24).unwrap();
    t.row(&[
        "decode step (1 token)".to_string(),
        format!("{:.2} ms", dec_us / 1e3),
        format!("{:.0} tok/s", 1.0 / (dec_us * 1e-6)),
    ]);
    let gemm_us = rt.bench("gemm", 12).unwrap();
    let gemm_flops = 2.0 * SEQ as f64 * D as f64 * D_FF as f64;
    t.row(&[
        "blocked GEMM kernel".to_string(),
        format!("{:.2} ms", gemm_us / 1e3),
        format!("{:.2} GFLOP/s", gemm_flops / (gemm_us * 1e3)),
    ]);
    let attn_us = rt.bench("attention", 12).unwrap();
    t.row(&[
        "fused attention kernel".to_string(),
        format!("{:.2} ms", attn_us / 1e3),
        format!("{:.0} head-rows/s", (HEADS * SEQ) as f64 / (attn_us * 1e-6)),
    ]);
    println!("{}", t.render());
    let _ = KV;

    // --- 4: the same workload through the analytical framework ----------
    println!("== analytical twin (HARP cost model, leaf+homogeneous) ==");
    let cascade = artifact_encoder_cascade();
    let opts = EvalOptions { samples: 300, ..EvalOptions::default() };
    let r = evaluate_cascade_on_config(
        &HarpClass::from_id("leaf+homo").unwrap(),
        &HardwareParams::default(),
        &cascade,
        &opts,
    )
    .unwrap();
    println!(
        "cascade MACs {:.3e} (= model maths of the executed artifact)\n\
         predicted latency on the Table III machine: {:.3e} cycles\n\
         predicted energy: {:.2} µJ   ({:.3e} mults/J)",
        r.stats.macs,
        r.stats.latency_cycles,
        r.stats.energy_pj * 1e-6,
        r.stats.mults_per_joule()
    );
    // Consistency gate: analytical MAC count equals the einsum maths of
    // the artifact model exactly.
    let expected_macs = (4 * SEQ * D * D // q,k,v,deproj
        + 2 * HEADS * SEQ * SEQ * (D / HEADS) // logit+attend
        + HEADS * SEQ * SEQ // softmax (modelled as k=1 einsum)
        + 2 * SEQ * D * D_FF) as f64; // ffn1+ffn2
    assert_eq!(r.stats.macs, expected_macs, "analytical/functional MAC mismatch");
    println!("\nanalytical MAC count matches the executed model exactly: OK");
    println!("e2e_validate OK");
}
