//! Deriving NEW accelerator classes from the taxonomy (paper §IV,
//! Table I rows (e), (g), (h) — combinations no prior work exhibits).
//!
//! Builds and evaluates:
//! - hierarchical + homogeneous (e): the same sub-accelerator type
//!   replicated at the leaf and at the LLB;
//! - hierarchical + intra-node (g): a shared-FSM pair spanning depths;
//! - compound (h): cross-node heterogeneity at the leaves combined with
//!   a cross-depth near-LLB unit (three sub-accelerators);
//! - hierarchical + clustered cross-node (f, Symphony-like).
//!
//! Run: `cargo run --release --example taxonomy_derive`

use harp::arch::partition::{HardwareParams, MachineConfig};
use harp::arch::taxonomy::{ComputePlacement, HarpClass, HeterogeneityLoc};
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::util::table::Table;
use harp::workload::transformer;

fn main() {
    let params = HardwareParams::default();
    let derived: Vec<(&str, HarpClass)> = vec![
        (
            "(e) hier+homogeneous",
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::Homogeneous),
        ),
        (
            "(f) hier+cross-node (clustered)",
            HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::CrossNode { clustered: true },
            ),
        ),
        (
            "(g) hier+intra-node",
            HarpClass::new(ComputePlacement::Hierarchical, HeterogeneityLoc::IntraNode),
        ),
        (
            "(h) compound (cross-node + cross-depth)",
            HarpClass::new(
                ComputePlacement::Hierarchical,
                HeterogeneityLoc::Compound(vec![
                    HeterogeneityLoc::cross_node(),
                    HeterogeneityLoc::CrossDepth,
                ]),
            ),
        ),
    ];

    // Validity: the taxonomy rejects the impossible leaf+cross-depth point.
    let invalid = HarpClass::new(ComputePlacement::LeafOnly, HeterogeneityLoc::CrossDepth);
    println!(
        "leaf+cross-depth validity: {:?} (cross-depth needs ≥2 compute levels)\n",
        invalid.validate().unwrap_err()
    );

    for (label, class) in &derived {
        class.validate().unwrap();
        let m = MachineConfig::build(class, &params).unwrap();
        println!("{label}\n{}", m.describe());
    }

    // Evaluate the derived classes on the Llama-2 decoder workload
    // against the four paper points.
    let wl = transformer::llama2();
    let cascade = transformer::cascade_for(&wl);
    let opts = EvalOptions { samples: 300, ..EvalOptions::default() };
    let base = evaluate_cascade_on_config(
        &HarpClass::from_id("leaf+homo").unwrap(),
        &params,
        &cascade,
        &opts,
    )
    .unwrap();
    let mut t = Table::new(&["class", "latency", "speedup", "energy µJ", "mults/J"]);
    let paper_points: Vec<(String, HarpClass)> = HarpClass::eval_points()
        .into_iter()
        .map(|(c, k)| (format!("({c}) {}", k.id()), k))
        .collect();
    for (label, class) in paper_points.iter().map(|(l, c)| (l.as_str(), c)).chain(
        derived.iter().map(|(l, c)| (*l, c)),
    ) {
        let r = evaluate_cascade_on_config(class, &params, &cascade, &opts).unwrap();
        t.row(&[
            label.to_string(),
            format!("{:.3e}", r.stats.latency_cycles),
            format!("{:.3}", base.stats.latency_cycles / r.stats.latency_cycles),
            format!("{:.1}", r.stats.energy_pj * 1e-6),
            format!("{:.3e}", r.stats.mults_per_joule()),
        ]);
    }
    println!("Llama-2 across all eight taxonomy points:\n{}", t.render());
    println!("taxonomy_derive OK");
}
