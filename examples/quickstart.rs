//! Quickstart: classify an accelerator, build a machine from the
//! taxonomy, and evaluate a workload on it — the 30-second tour of the
//! public API.
//!
//! Run: `cargo run --release --example quickstart`

use harp::arch::partition::{HardwareParams, MachineConfig};
use harp::arch::taxonomy::{classify, HarpClass};
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::workload::transformer;

fn main() {
    // 1. The taxonomy: classify a known accelerator.
    let w = classify("neupim").expect("NeuPIM is in Table I");
    println!("{} is {} — {}\n", w.name, w.class, w.remark);

    // 2. Build a machine for a taxonomy point under Table III resources.
    let class = HarpClass::from_id("hier+xdepth").unwrap();
    let params = HardwareParams::default();
    let machine = MachineConfig::build(&class, &params).unwrap();
    println!("{}", machine.describe());

    // 3. Evaluate the BERT-large encoder cascade on two taxonomy points.
    let cascade = transformer::encoder_cascade(&transformer::bert_large());
    println!("{}", cascade.describe());
    let opts = EvalOptions { samples: 300, ..EvalOptions::default() };
    for id in ["leaf+homo", "hier+xdepth"] {
        let class = HarpClass::from_id(id).unwrap();
        let r = evaluate_cascade_on_config(&class, &params, &cascade, &opts).unwrap();
        println!(
            "{id:<14} latency {:>10.3e} cycles   energy {:>9.1} µJ   {:>9.3e} mults/J",
            r.stats.latency_cycles,
            r.stats.energy_pj * 1e-6,
            r.stats.mults_per_joule()
        );
    }
    println!("\nquickstart OK");
}
