//! Inter-cascade partitioning study: GPT-3 / Llama-2 (paper §II-B,
//! §V-A, Fig 10) plus a serving-batch ablation the paper's setup
//! implies but does not plot.
//!
//! Decoder workloads decouple into prefill (high-reuse, compute-bound)
//! and decode (low-reuse, bandwidth-bound) sub-cascades with no cross
//! edges: the heterogeneous machine hides the entire decode stream
//! behind prefill compute, which a time-shared homogeneous machine
//! cannot.
//!
//! Run: `cargo run --release --example gpt_inter_cascade`

use harp::arch::partition::HardwareParams;
use harp::arch::taxonomy::HarpClass;
use harp::coordinator::experiment::{evaluate_cascade_on_config, EvalOptions};
use harp::util::table::Table;
use harp::workload::transformer;

fn main() {
    let opts = EvalOptions { samples: 400, ..EvalOptions::default() };
    let params = HardwareParams::default();

    for wl in [transformer::llama2(), transformer::gpt3()] {
        let cascade = transformer::cascade_for(&wl);
        println!(
            "=== {} (d_model {}, batch {}, kv groups {}) ===",
            wl.name,
            wl.d_model,
            wl.batch,
            wl.group_size()
        );
        let mut t = Table::new(&["machine", "latency", "speedup", "busy high", "busy low"]);
        let base = evaluate_cascade_on_config(
            &HarpClass::from_id("leaf+homo").unwrap(),
            &params,
            &cascade,
            &opts,
        )
        .unwrap();
        for id in ["leaf+homo", "leaf+xnode", "leaf+intra", "hier+xdepth"] {
            let r = evaluate_cascade_on_config(
                &HarpClass::from_id(id).unwrap(),
                &params,
                &cascade,
                &opts,
            )
            .unwrap();
            let busy: Vec<String> =
                r.stats.busy_fraction.iter().map(|b| format!("{:.0}%", b * 100.0)).collect();
            t.row(&[
                id.into(),
                format!("{:.3e}", r.stats.latency_cycles),
                format!("{:.3}", base.stats.latency_cycles / r.stats.latency_cycles),
                busy.first().cloned().unwrap_or_default(),
                busy.get(1).cloned().unwrap_or_default(),
            ]);
        }
        println!("{}", t.render());

        // Fig 10: bandwidth-partition sensitivity on the cross-node point.
        let mut f10 = Table::new(&["low-reuse bw share", "latency", "speedup vs homo"]);
        for frac in [0.9, 0.75, 0.5, 0.25] {
            let mut o = opts.clone();
            o.bw_frac_low = Some(frac);
            let r = evaluate_cascade_on_config(
                &HarpClass::from_id("leaf+xnode").unwrap(),
                &params,
                &cascade,
                &o,
            )
            .unwrap();
            f10.row(&[
                format!("{:.0}%", frac * 100.0),
                format!("{:.3e}", r.stats.latency_cycles),
                format!("{:.3}", base.stats.latency_cycles / r.stats.latency_cycles),
            ]);
        }
        println!("bandwidth partitioning (Fig 10):\n{}", f10.render());
    }

    // Ablation: the serving batch moves the prefill/decode balance and
    // with it the heterogeneous advantage (decode KV streaming grows
    // with batch, prefill compute grows linearly too, but the small
    // low-reuse unit saturates).
    println!("=== serving-batch ablation (Llama-2, leaf+xnode vs leaf+homo) ===");
    let mut ab = Table::new(&["batch", "homo latency", "xnode latency", "het speedup"]);
    for batch in [16u64, 32, 64, 96] {
        let mut wl = transformer::llama2();
        wl.batch = batch;
        let cascade = transformer::cascade_for(&wl);
        let homo = evaluate_cascade_on_config(
            &HarpClass::from_id("leaf+homo").unwrap(),
            &params,
            &cascade,
            &opts,
        )
        .unwrap();
        let het = evaluate_cascade_on_config(
            &HarpClass::from_id("leaf+xnode").unwrap(),
            &params,
            &cascade,
            &opts,
        )
        .unwrap();
        ab.row(&[
            batch.to_string(),
            format!("{:.3e}", homo.stats.latency_cycles),
            format!("{:.3e}", het.stats.latency_cycles),
            format!("{:.3}", homo.stats.latency_cycles / het.stats.latency_cycles),
        ]);
    }
    println!("{}", ab.render());
    println!("gpt_inter_cascade OK");
}
